"""Fault-tolerant serving runtime: typed error taxonomy, deterministic
retry backoff, circuit-breaker degradation ladder, deadline watchdog,
signal-integrity quarantine, grating-cache checksum self-heal, and the
seeded chaos injector (tests/test_serve.py covers the healthy paths)."""

import threading
import time
from concurrent.futures import Future

import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import fidelity as fid
from repro.core.engine import GratingCache
from repro.core.sthc import STHC, STHCConfig
from repro.distributed.fault import ChaosInjector, ChaosRule, InjectedFault
from repro.launch.resilience import (
    BatchExecutionError,
    CircuitBreaker,
    DeadlineExceeded,
    DegradationLadder,
    RequestRejected,
    RetryPolicy,
    SchedulerClosed,
    ServingError,
    TenantQuarantined,
    Watchdog,
    is_transient,
    is_validation_error,
)
from repro.launch.serve import (
    MicrobatchScheduler,
    VideoSearchConfig,
    VideoSearchServer,
)


def _kernels(seed, O=2, kt=3):
    rng = np.random.RandomState(seed)
    return jnp.asarray(rng.randn(O, 1, 3, 4, kt).astype(np.float32))


def _clip(seed, B=1, T=20, H=12, W=12):
    rng = np.random.RandomState(100 + seed)
    return jnp.asarray(rng.rand(B, 1, H, W, T).astype(np.float32))


def _server(n_tenants=2, **cfg_kw):
    cfg = VideoSearchConfig(window_frames=8, **cfg_kw)
    server = VideoSearchServer(frame_hw=(12, 12), cfg=cfg)
    for i in range(n_tenants):
        server.add_tenant(f"t{i}", _kernels(i))
    return server


class _FakeClock:
    def __init__(self, t=0.0):
        self.t = t

    def __call__(self):
        return self.t


# -- primitives: backoff, breaker, ladder, watchdog ------------------------


def test_retry_delays_deterministic_and_capped():
    """The decorrelated-jitter schedule is a pure function of the seed:
    identical on every delays() call, bounded by [0, cap], one delay per
    allowed retry."""
    pol = RetryPolicy(max_retries=5, base_s=0.001, cap_s=0.01, seed=7)
    a, b = list(pol.delays()), list(pol.delays())
    assert a == b and len(a) == 5
    assert all(0.0 < d <= pol.cap_s for d in a)
    # a different seed yields a different schedule (decorrelated jitter
    # is stochastic across seeds, deterministic within one)
    assert a != list(RetryPolicy(max_retries=5, cap_s=0.01, seed=8).delays())


def test_circuit_breaker_trip_halfopen_recover():
    clock = _FakeClock()
    brk = CircuitBreaker(failure_threshold=3, recovery_s=1.0, clock=clock)
    assert brk.state == "closed" and brk.allow()
    brk.record_failure()
    brk.record_failure()
    assert brk.state == "closed"  # below threshold
    brk.record_failure()
    assert brk.state == "open" and brk.trips == 1
    assert not brk.allow()  # inside the recovery window
    clock.t += 1.5
    assert brk.allow()  # past the window: admit the half-open probe
    assert brk.state == "half_open"
    brk.record_success()
    assert brk.state == "closed" and brk.recoveries == 1
    # a non-consecutive failure pattern never trips: success resets
    brk.record_failure()
    brk.record_success()
    brk.record_failure()
    brk.record_failure()
    assert brk.state == "closed" and brk.trips == 1


def test_circuit_breaker_halfopen_failure_reopens():
    clock = _FakeClock()
    brk = CircuitBreaker(failure_threshold=1, recovery_s=1.0, clock=clock)
    brk.record_failure()
    assert brk.state == "open"
    clock.t += 1.0
    assert brk.allow() and brk.state == "half_open"
    brk.record_failure()  # the probe failed: straight back to open
    assert brk.state == "open" and brk.trips == 2
    snap = brk.snapshot()
    assert snap["failures"] == 2 and snap["recoveries"] == 0


def test_ladder_degrades_and_recovers():
    clock = _FakeClock()
    ladder = DegradationLadder(failure_threshold=2, recovery_s=1.0, clock=clock)
    assert ladder.select() == "pooled"
    ladder.report("pooled", ok=False)
    ladder.report("pooled", ok=False)
    assert ladder.peek() == "sequential"  # pooled breaker open
    assert ladder.select() == "sequential"
    # sequential fails too -> bottom rung (breaker-less: always serves)
    ladder.report("sequential", ok=False)
    ladder.report("sequential", ok=False)
    assert ladder.select() == "single"
    ladder.report("single", ok=False)  # no breaker to trip
    assert ladder.select() == "single"
    # recovery: the pooled probe is admitted first and heals the ladder
    clock.t += 1.5
    assert ladder.select() == "pooled"
    ladder.report("pooled", ok=True)
    assert ladder.peek() == "pooled"
    m = ladder.metrics()
    assert m["mode"] == "pooled"
    assert m["breakers"]["pooled"]["recoveries"] == 1
    assert m["breakers"]["pooled"]["trips"] == 1


def test_error_taxonomy_fields_and_classification():
    err = TenantQuarantined("bad rows", tenant="a", batch_id=3)
    assert isinstance(err, ServingError) and isinstance(err, RuntimeError)
    assert err.tenant == "a" and err.batch_id == 3
    for cls in (RequestRejected, DeadlineExceeded, BatchExecutionError,
                SchedulerClosed):
        assert issubclass(cls, ServingError)
    assert is_transient(InjectedFault("dispatch"))
    assert not is_transient(RuntimeError("boom"))
    assert is_validation_error(KeyError("unknown tenant"))
    assert not is_validation_error(InjectedFault("dispatch"))
    # chained root cause survives the typed wrapper
    root = InjectedFault("dispatch")
    wrapped = BatchExecutionError("gave up", tenant="a", batch_id=1)
    wrapped.__cause__ = root
    assert wrapped.__cause__ is root


def test_watchdog_sweep_expires_and_drops_done():
    clock = _FakeClock(10.0)
    expired_tenants = []
    dog = Watchdog(
        interval_s=60.0,  # effectively manual: we drive sweep() ourselves
        clock=clock,
        on_expire=expired_tenants.append,
    )
    try:
        overdue, healthy, undeadlined = Future(), Future(), Future()
        dog.track(overdue, deadline=11.0, tenant="a")
        dog.track(healthy, deadline=99.0, tenant="b")
        dog.track(undeadlined, deadline=None, tenant="c")  # not registered
        assert dog.tracked == 2
        healthy.set_result({"ok": True})  # resolved before its deadline
        clock.t = 12.0
        assert dog.sweep() == 1
        assert dog.expired == 1 and expired_tenants == ["a"]
        with pytest.raises(DeadlineExceeded):
            overdue.result(timeout=0)
        assert dog.tracked == 0  # done + expired both swept
        assert not undeadlined.done()
    finally:
        dog.close()


# -- scheduler lifecycle: deadlines, retries, degradation, shutdown --------


def test_scheduler_deadline_exceeded_is_typed():
    """A deadline that cannot be met resolves the future with
    DeadlineExceeded (typed, carrying the tenant) even while the batcher
    is wedged inside a slow dispatch — the watchdog is the backstop."""
    server = _server(1)
    orig = server.search_batch
    release = threading.Event()

    def wedged(reqs, pooled=None, **kw):
        release.wait(timeout=10.0)  # hold the batcher mid-dispatch
        return orig(reqs, pooled=pooled, **kw)

    server.search_batch = wedged
    with MicrobatchScheduler(
        server, max_queue=8, max_batch=1, batch_wait_s=0.0,
        watchdog_interval_s=0.005,
    ) as sched:
        wedger = sched.submit("t0", _clip(0))
        doomed = sched.submit("t0", _clip(1), deadline_s=0.05)
        with pytest.raises(DeadlineExceeded) as ei:
            doomed.result(timeout=10)
        assert ei.value.tenant == "t0"
        release.set()
        wedger.result(timeout=30)  # the wedged request still completes
        m = sched.metrics()
    assert m["deadline_missed"] >= 1 and m["watchdog_expired"] >= 1
    assert m["failed"] >= 1


def test_scheduler_default_deadline_applies():
    server = _server(1)
    server.search_batch = lambda reqs, pooled=None, **kw: time.sleep(5)
    with MicrobatchScheduler(
        server, max_queue=4, max_batch=1, batch_wait_s=0.0,
        default_deadline_s=0.05, watchdog_interval_s=0.005,
    ) as sched:
        fut = sched.submit("t0", _clip(0))
        with pytest.raises(DeadlineExceeded):
            fut.result(timeout=10)


def test_scheduler_close_resolves_queued_futures():
    """Shutdown never strands a future: still-queued requests resolve
    with SchedulerClosed, and submit() after close() raises it too."""
    server = _server(1)
    started = threading.Event()
    release = threading.Event()

    def wedged(reqs, pooled=None, **kw):
        started.set()
        release.wait(timeout=10.0)
        raise InjectedFault("dispatch")  # the in-flight one fails too

    server.search_batch = wedged
    sched = MicrobatchScheduler(
        server, max_queue=8, max_batch=1, batch_wait_s=0.0,
        retry=RetryPolicy(max_retries=0),
    )
    inflight = sched.submit("t0", _clip(0))
    assert started.wait(timeout=10)
    queued = [sched.submit("t0", _clip(i)) for i in range(1, 4)]
    closer = threading.Thread(target=sched.close)
    closer.start()
    release.set()
    closer.join(timeout=30)
    assert not closer.is_alive()
    for f in queued:
        with pytest.raises(SchedulerClosed):
            f.result(timeout=0)
    # the in-flight request resolved (typed), not hung
    with pytest.raises(ServingError):
        inflight.result(timeout=0)
    with pytest.raises(SchedulerClosed):
        sched.submit("t0", _clip(9))
    sched.close()  # idempotent


def test_scheduler_retries_transient_fault_then_succeeds():
    """A transient dispatch fault (truthy .transient) is retried under
    the seeded backoff and the request completes; the retries counter
    records the recovery work."""
    server = _server(1)
    orig = server.search_batch
    fails = {"n": 2}

    def flaky(reqs, pooled=None, **kw):
        if fails["n"] > 0:
            fails["n"] -= 1
            raise InjectedFault("dispatch", "flaky")
        return orig(reqs, pooled=pooled, **kw)

    server.search_batch = flaky
    with MicrobatchScheduler(
        server, max_queue=4, max_batch=1, batch_wait_s=0.0,
        retry=RetryPolicy(max_retries=4, base_s=1e-4, cap_s=1e-3, seed=0),
        # threshold above the fault count: the ladder must not degrade
        ladder=DegradationLadder(failure_threshold=5),
    ) as sched:
        out = sched.submit("t0", _clip(0)).result(timeout=60)
        m = sched.metrics()
    assert np.isfinite(out["scores"]).all()
    assert m["retries"] == 2 and m["completed"] == 1 and m["failed"] == 0
    assert m["mode"] == "pooled"  # breaker saw 2 < 5 consecutive failures


def test_scheduler_degrades_to_sequential_when_pooled_path_fails():
    """A hard pooled-path outage trips the breaker and the SAME request
    is re-dispatched on the sequential rung — degradation is not a
    retry and must not consume the backoff budget."""
    server = _server(2)
    orig = server.search_batch

    def pooled_down(reqs, pooled=None, **kw):
        if pooled is not False:  # the pooled rung passes pooled=None
            raise InjectedFault("dispatch", "pooled path down")
        return orig(reqs, pooled=False, **kw)

    server.search_batch = pooled_down
    with MicrobatchScheduler(
        server, max_queue=8, max_batch=4, batch_wait_s=0.01,
        retry=RetryPolicy(max_retries=0),  # no retry budget at all
        ladder=DegradationLadder(failure_threshold=1, recovery_s=60.0),
    ) as sched:
        outs = [
            sched.submit(f"t{i % 2}", _clip(i)).result(timeout=60)
            for i in range(3)
        ]
        m = sched.metrics()
    for out in outs:
        assert np.isfinite(out["scores"]).all()
    assert m["completed"] == 3 and m["failed"] == 0
    assert m["mode"] == "sequential"
    assert m["ladder"]["breakers"]["pooled"]["trips"] >= 1


def test_scheduler_validation_error_passes_through_unwrapped():
    """Caller errors are not retried, not breaker-counted, and reach
    the caller as-is (KeyError for an unknown tenant)."""
    server = _server(1)
    with MicrobatchScheduler(
        server, max_queue=4, max_batch=2, batch_wait_s=0.01
    ) as sched:
        bad = sched.submit("nope", _clip(0))
        with pytest.raises(KeyError, match="unknown tenant"):
            bad.result(timeout=60)
        m = sched.metrics()
    assert m["failed"] == 1 and m["retries"] == 0
    assert m["ladder"]["breakers"]["pooled"]["trips"] == 0


# -- signal integrity: quarantine + cache checksum -------------------------


def test_quarantine_isolates_poisoned_row_bitwise():
    """One NaN clip in a pooled batch quarantines exactly that request;
    the healthy requests' scores are BITWISE identical to the same batch
    composition served with a clean fourth clip."""
    server = _server(4)
    healthy = [("t0", _clip(0)), ("t1", _clip(1)), ("t2", _clip(2))]
    clean4 = _clip(3)
    poisoned4 = np.array(clean4, copy=True)
    poisoned4[0, 0, 0, 0, :] = np.nan
    ref = server.search_batch(healthy + [("t3", jnp.asarray(clean4))])
    out = server.search_batch(healthy + [("t3", jnp.asarray(poisoned4))])
    for r, o in zip(ref[:3], out[:3]):
        assert np.array_equal(np.asarray(r["scores"]), np.asarray(o["scores"]))
    assert isinstance(out[3], TenantQuarantined)
    assert out[3].tenant == "t3"
    assert server.metrics()["quarantined"] == 1
    # the single-request front door raises the typed error
    with pytest.raises(TenantQuarantined):
        server.search(jnp.asarray(poisoned4), tenant="t3")


def test_scheduler_routes_quarantine_into_the_one_future():
    server = _server(2)
    bad = np.array(_clip(0), copy=True)
    bad[0, 0, 0, 0, :] = np.nan
    with MicrobatchScheduler(
        server, max_queue=8, max_batch=4, batch_wait_s=0.05
    ) as sched:
        good = sched.submit("t0", _clip(1))
        doomed = sched.submit("t1", jnp.asarray(bad))
        assert np.isfinite(good.result(timeout=60)["scores"]).all()
        with pytest.raises(TenantQuarantined) as ei:
            doomed.result(timeout=60)
        assert ei.value.tenant == "t1"
        m = sched.metrics()
    assert m["quarantined"] == 1 and m["completed"] == 1


def test_guard_scores_off_restores_raw_delivery():
    server = _server(1, guard_scores=False)
    bad = np.array(_clip(0), copy=True)
    bad[0, 0, 0, 0, :] = np.nan
    out = server.search_batch([("t0", jnp.asarray(bad))])[0]
    assert isinstance(out, dict)  # no quarantine: raw NaNs delivered
    assert not np.isfinite(out["scores"]).all()


def test_cache_verify_detects_corruption_and_self_heals():
    """Corrupting a resident grating is caught by the fetch checksum:
    the entry is dropped, transparently re-recorded, and the fresh
    entry is clean; integrity_failures counts the detection."""
    cache = GratingCache(max_entries=4, verify=True)
    sthc = STHC(STHCConfig(fidelity=fid.ideal()), cache=cache)
    sthc.record(_kernels(0), (12, 12, 8))
    key = next(iter(cache._entries))
    entry = cache._entries[key]
    # bit-rot stand-in: NaN-poison the resident storage plane in place
    if entry.effective is not None:
        entry.effective = entry.effective * jnp.nan
    else:
        entry.eff_re = entry.eff_re * jnp.nan
    g2 = sthc.record(_kernels(0), (12, 12, 8))  # fetch -> detect -> heal
    assert cache.stats()["integrity_failures"] == 1
    assert cache.stats()["misses"] == 2  # the self-heal re-record
    re, im = g2.planes
    assert bool(jnp.isfinite(re).all()) and bool(jnp.isfinite(im).all())
    assert cache._entries[key] is g2  # the healed entry is resident


def test_cache_verify_off_by_default_and_free():
    cache = GratingCache(max_entries=2)
    assert cache.stats()["verify"] is False
    assert cache.stats()["integrity_failures"] == 0


# -- chaos injector --------------------------------------------------------


def test_chaos_injector_is_seed_deterministic():
    def run(seed):
        chaos = ChaosInjector(
            [ChaosRule("dispatch", "raise", rate=0.3)], seed=seed
        )
        fired = []
        for i in range(50):
            try:
                chaos.on("dispatch")
                fired.append(0)
            except InjectedFault:
                fired.append(1)
        return fired, chaos.stats()

    a, sa = run(seed=3)
    b, sb = run(seed=3)
    c, _ = run(seed=4)
    assert a == b and sa == sb
    assert a != c  # different seed, different storm
    assert sa["events"]["dispatch"] == 50
    assert sa["injected"]["dispatch/raise"] == sum(a) == sa["total_injected"]


def test_chaos_at_indices_fire_once_and_mode_filters():
    evicted = []
    chaos = ChaosInjector(
        [
            ChaosRule("cache_fetch", "call", at=(2,), action=lambda: evicted.append(1)),
            ChaosRule("dispatch", "raise", at=(1,), mode="pooled"),
        ],
        seed=0,
    )
    for _ in range(5):
        chaos.on("cache_fetch")
    assert evicted == [1]  # index 2 fired exactly once
    chaos.on("dispatch", mode="sequential")  # event 1, wrong mode: no fire
    chaos.on("dispatch", mode="pooled")  # event 2: index 1 already passed
    assert chaos.stats()["injected"].get("dispatch/raise") is None


def test_chaos_nan_rule_poisons_a_copy():
    chaos = ChaosInjector([ChaosRule("readout", "nan", at=(1,))], seed=0)
    peak = np.ones((3, 2), dtype=np.float32)
    out = chaos.on("readout", payload=peak)
    assert np.isfinite(peak).all()  # caller's array untouched
    assert np.isnan(out).any() and np.isnan(out).sum() == 2  # one row


# -- concurrency: eviction races under tenant churn ------------------------


def test_tenant_churn_race_leaves_no_orphan_cache_entries():
    """Threads hammer add/remove/search while the shared cache evicts;
    afterwards every cache entry maps to a live tenant and the verify
    checksum table stays in lockstep with the entry table."""
    cfg = VideoSearchConfig(
        window_frames=8, cache_entries=3, verify_gratings=True
    )
    server = VideoSearchServer(frame_hw=(12, 12), cfg=cfg)
    for i in range(3):
        server.add_tenant(f"base{i}", _kernels(i))
    stop = threading.Event()
    errors = []

    def churn(tid):
        name = f"churn{tid}"
        k = 0
        while not stop.is_set():
            try:
                server.add_tenant(name, _kernels(10 + tid + k))
                server.search(_clip(tid), tenant=name)
                server.remove_tenant(name)
                k += 1
            except KeyError:
                pass  # lost a remove/search race with ourselves: fine
            except Exception as exc:  # noqa: BLE001
                errors.append(exc)
                return

    def searcher():
        while not stop.is_set():
            try:
                server.search(_clip(0), tenant="base0")
                server.search(_clip(1), tenant="base2")
            except Exception as exc:  # noqa: BLE001
                errors.append(exc)
                return

    threads = [threading.Thread(target=churn, args=(i,)) for i in range(2)]
    threads.append(threading.Thread(target=searcher))
    for t in threads:
        t.start()
    time.sleep(1.5)
    stop.set()
    for t in threads:
        t.join(timeout=30)
        assert not t.is_alive()
    assert not errors, errors
    live_keys = {t.key for t in server._tenants.values()}
    with server.cache._lock:
        cached = set(server.cache._entries)
        sums = set(server.cache._sums)
    assert cached <= live_keys  # no orphan gratings survive the churn
    assert sums <= cached  # checksum table never outlives its entries
    stats = server.cache.stats()
    assert stats["entries"] <= 3 and stats["bytes"] >= 0


def test_retry_delays_deadline_truncation_fake_clock():
    """No retry may be scheduled past the remaining deadline budget: the
    schedule ends at the first delay that would land at/after the
    deadline, and the un-truncated prefix is the same pinned sequence
    as the deadline-free schedule (jitter draws are consumed
    identically either way)."""
    policy = RetryPolicy(max_retries=5, base_s=0.01, cap_s=10.0, seed=7)
    clock = _FakeClock(t=100.0)
    full = list(policy.delays())
    assert len(full) == 5

    # generous deadline: full schedule, identical values
    assert list(policy.delays(deadline=1e9, clock=clock)) == full

    # deadline that admits exactly the first two delays: walk the fake
    # clock the way the scheduler does (sleep = advance)
    cutoff = 100.0 + full[0] + full[1] + 0.5 * full[2]
    clock.t = 100.0
    got = []
    for d in policy.delays(deadline=cutoff, clock=clock):
        got.append(d)
        clock.t += d  # the sleep
    assert got == full[:2]

    # a deadline already in the past yields nothing
    clock.t = 100.0
    assert list(policy.delays(deadline=99.0, clock=clock)) == []

    # boundary: a delay landing exactly ON the deadline is not taken
    clock.t = 0.0
    assert list(policy.delays(deadline=full[0], clock=clock)) == []
