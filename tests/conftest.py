# NOTE: deliberately NO XLA_FLAGS device-count override here — smoke tests
# and benches must see the real single CPU device.  Multi-device tests
# (sharding/elastic) spawn subprocesses that set their own XLA_FLAGS.
import numpy as np
import pytest


@pytest.fixture
def rng():
    return np.random.RandomState(0)
