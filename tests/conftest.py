# NOTE: deliberately NO XLA_FLAGS device-count override here — smoke tests
# and benches must see the real single CPU device.  Multi-device tests
# (sharding/elastic) spawn subprocesses that set their own XLA_FLAGS.
import os

import numpy as np
import pytest

import jax

# Runtime strictness for the whole suite: implicit rank promotion
# ((3,) + (4, 3) silently broadcasting) is exactly the kind of shape bug
# the correlator's (B, O, H, W, T) tensors make easy to write and hard
# to see — make it a hard error everywhere tests touch.
jax.config.update("jax_numpy_rank_promotion", "raise")

# Opt-in NaN debugging: REPRO_DEBUG_NANS=1 re-runs any jitted computation
# that produced a NaN in op-by-op mode and raises at the culprit.  Not the
# default — it disables async dispatch and some tests (chaos/quarantine)
# produce NaNs on purpose.
if os.environ.get("REPRO_DEBUG_NANS") == "1":
    jax.config.update("jax_debug_nans", True)


@pytest.fixture
def rng():
    return np.random.RandomState(0)
