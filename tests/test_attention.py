"""Blockwise (flash-style) and decode attention vs the naive oracle."""

import jax
import jax.numpy as jnp
import numpy as np
from _hypo import given, settings, st  # hypothesis, or deterministic fallback

from repro.models import common


def _naive(q, k, v, causal, q_offset=0, kv_len=None):
    B, Sq, H, D = q.shape
    G = k.shape[2]
    kr = jnp.repeat(k, H // G, 2)
    vr = jnp.repeat(v, H // G, 2)
    s = jnp.einsum("bqhd,bkhd->bhqk", q, kr) / np.sqrt(D)
    Sk = k.shape[1]
    qpos = q_offset + jnp.arange(Sq)
    kpos = jnp.arange(Sk)
    if causal:
        mask = qpos[:, None] >= kpos[None, :]
        s = jnp.where(mask[None, None], s, -1e30)
    if kv_len is not None:
        valid = kpos[None, :] < kv_len[:, None]
        s = jnp.where(valid[:, None, None, :], s, -1e30)
    p = jax.nn.softmax(s, -1)
    return jnp.einsum("bhqk,bkhd->bqhd", p, vr)


@settings(max_examples=10, deadline=None)
@given(
    sq=st.integers(1, 24),
    sk=st.integers(1, 48),
    h=st.sampled_from([2, 4, 6]),
    g_div=st.sampled_from([1, 2]),
    block=st.sampled_from([4, 8, 16]),
    causal=st.booleans(),
)
def test_blockwise_matches_naive(sq, sk, h, g_div, block, causal):
    if h % g_div:
        g_div = 1
    g = h // g_div
    rng = np.random.RandomState(sq * 100 + sk)
    B, D = 2, 8
    q = jnp.asarray(rng.randn(B, sq, h, D).astype(np.float32))
    k = jnp.asarray(rng.randn(B, sk, g, D).astype(np.float32))
    v = jnp.asarray(rng.randn(B, sk, g, D).astype(np.float32))
    # causal with Sq == Sk semantics (training); offset aligns ends
    off = max(sk - sq, 0) if causal else 0
    got = common.blockwise_attention(q, k, v, causal=causal, q_offset=off,
                                     block_k=block)
    ref = _naive(q, k, v, causal, q_offset=off)
    np.testing.assert_allclose(got, ref, atol=2e-5 * sk + 1e-5)


def test_decode_attention_matches_naive():
    rng = np.random.RandomState(0)
    B, M, H, G, D = 3, 33, 8, 2, 16
    q = jnp.asarray(rng.randn(B, 1, H, D).astype(np.float32))
    k = jnp.asarray(rng.randn(B, M, G, D).astype(np.float32))
    v = jnp.asarray(rng.randn(B, M, G, D).astype(np.float32))
    kv_len = jnp.asarray([5, 17, 33], jnp.int32)
    got = common.decode_attention(q, k, v, kv_len)
    ref = _naive(q, k, v, causal=False, kv_len=kv_len)
    np.testing.assert_allclose(got, ref, atol=1e-4)


def test_blockwise_kv_len_masking():
    rng = np.random.RandomState(1)
    B, Sq, Sk, H, D = 2, 4, 32, 2, 8
    q = jnp.asarray(rng.randn(B, Sq, H, D).astype(np.float32))
    k = jnp.asarray(rng.randn(B, Sk, H, D).astype(np.float32))
    v = jnp.asarray(rng.randn(B, Sk, H, D).astype(np.float32))
    kv_len = jnp.asarray([9, 20], jnp.int32)
    got = common.blockwise_attention(q, k, v, causal=False, kv_len=kv_len,
                                     block_k=8)
    ref = _naive(q, k, v, causal=False, kv_len=kv_len)
    np.testing.assert_allclose(got, ref, atol=1e-4)
    # garbage beyond kv_len must not leak: perturb masked keys
    k2 = k.at[:, -5:].set(1e3)
    got2 = common.blockwise_attention(q, k2, v, causal=False, kv_len=kv_len,
                                      block_k=8)
    np.testing.assert_allclose(got2, got, atol=1e-4)
