# lint: disable-file=KC301
"""Suppressed KC301 twin: same missing ref.py/test, silenced."""
