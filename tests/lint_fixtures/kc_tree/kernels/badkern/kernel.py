"""Seeded KC301: a kernels/<name>/kernel.py with no sibling ref.py
oracle and no oracle-equivalence test.  Never executed."""
