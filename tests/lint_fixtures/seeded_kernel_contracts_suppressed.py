# lint: disable-file=KC302,KC303
"""Suppressed twin of seeded_kernel_contracts.py.  Never executed."""

import jax
from jax.experimental import pallas as pl


def _noop_kernel(x_ref, o_ref):
    o_ref[...] = x_ref[...]


def seeded_blockspec_arity(x):
    return pl.pallas_call(
        _noop_kernel,
        grid=(4, 4),
        in_specs=[pl.BlockSpec((1, 1), lambda i: (i, 0))],
        out_specs=pl.BlockSpec((1, 1), lambda i, j: (i, j)),
        out_shape=jax.ShapeDtypeStruct(x.shape, x.dtype),
    )(x)


def seeded_unpadded_grid(x, block_f):
    B, F = x.shape
    return pl.pallas_call(
        _noop_kernel,
        grid=(B, F // block_f),
        in_specs=[pl.BlockSpec((1, block_f), lambda b, f: (b, f))],
        out_specs=pl.BlockSpec((1, block_f), lambda b, f: (b, f)),
        out_shape=jax.ShapeDtypeStruct(x.shape, x.dtype),
    )(x)
