# lint: disable-file=TS101,TS102,TS103,TS104,TS105,TS106
"""Suppressed twin of seeded_trace_safety.py: identical violations, all
silenced by the file-level disable above.  Never executed."""

import jax
import jax.numpy as jnp
import numpy as np
from jax.experimental import pallas as pl

_SEEDED_N_DEVICES = jax.device_count()


@jax.jit
def seeded_tracer_branch(x, lo):
    if x.sum() > 0:
        return x + lo
    while lo > 0:
        lo = lo - 1
    return x


@jax.jit
def seeded_host_calls(x):
    v = float(x)
    w = np.abs(x)
    u = x.item()
    return v, w, u


def seeded_static_list(fn):
    return jax.jit(fn, static_argnames=["n", "mode"])


def _seeded_dot_kernel(x_ref, g_ref, o_ref):
    o_ref[...] = jnp.dot(x_ref[...], g_ref[...])


def seeded_launch(x, g):
    return pl.pallas_call(
        _seeded_dot_kernel,
        out_shape=jax.ShapeDtypeStruct(x.shape, x.dtype),
    )(x, g)


def seeded_bf16_accum(plane):
    lo = plane.astype(jnp.bfloat16)
    acc = lo + lo
    acc += lo
    return acc
