"""Seeded kernel-contract violations (KC302, KC303).  Never executed."""

import jax
from jax.experimental import pallas as pl


def _noop_kernel(x_ref, o_ref):
    o_ref[...] = x_ref[...]


def seeded_blockspec_arity(x):
    # KC302: 2-axis grid, but the in_spec index map declares one axis.
    return pl.pallas_call(
        _noop_kernel,
        grid=(4, 4),
        in_specs=[pl.BlockSpec((1, 1), lambda i: (i, 0))],
        out_specs=pl.BlockSpec((1, 1), lambda i, j: (i, j)),
        out_shape=jax.ShapeDtypeStruct(x.shape, x.dtype),
    )(x)


def seeded_unpadded_grid(x, block_f):
    # KC303: F is a raw input dim — neither pad-derived nor asserted
    # divisible by block_f, so a non-dividing tile drops remainder rows.
    B, F = x.shape
    return pl.pallas_call(
        _noop_kernel,
        grid=(B, F // block_f),
        in_specs=[pl.BlockSpec((1, block_f), lambda b, f: (b, f))],
        out_specs=pl.BlockSpec((1, block_f), lambda b, f: (b, f)),
        out_shape=jax.ShapeDtypeStruct(x.shape, x.dtype),
    )(x)


def padded_grid_ok(x, block_f):
    # Contract satisfied: dividend is pad-derived.
    B, F = x.shape
    f_pad = (-F) % block_f
    Fp = F + f_pad
    return pl.pallas_call(
        _noop_kernel,
        grid=(B, Fp // block_f),
        in_specs=[pl.BlockSpec((1, block_f), lambda b, f: (b, f))],
        out_specs=pl.BlockSpec((1, block_f), lambda b, f: (b, f)),
        out_shape=jax.ShapeDtypeStruct(x.shape, x.dtype),
    )(x)


def asserted_grid_ok(x, block_f):
    # Contract satisfied: divisibility asserted.
    B, F = x.shape
    assert F % block_f == 0
    return pl.pallas_call(
        _noop_kernel,
        grid=(B, F // block_f),
        in_specs=[pl.BlockSpec((1, block_f), lambda b, f: (b, f))],
        out_specs=pl.BlockSpec((1, block_f), lambda b, f: (b, f)),
        out_shape=jax.ShapeDtypeStruct(x.shape, x.dtype),
    )(x)
