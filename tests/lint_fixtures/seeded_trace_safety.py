"""Seeded trace-safety violations (TS101–TS106).  Never executed."""

import functools

import jax
import jax.numpy as jnp
import numpy as np
from jax.experimental import pallas as pl

# TS106: device query at import time pins the backend before XLA_FLAGS
# (e.g. forced host-device fan-out) can take effect.
_SEEDED_N_DEVICES = jax.device_count()


@jax.jit
def seeded_tracer_branch(x, lo):
    # TS101: Python branch on a traced value -> retrace per boolean,
    # or a ConcretizationTypeError at best.
    if x.sum() > 0:
        return x + lo
    while lo > 0:
        lo = lo - 1
    return x


@jax.jit
def seeded_host_calls(x):
    # TS102: host syncs inside a jitted function.
    v = float(x)
    w = np.abs(x)
    u = x.item()
    return v, w, u


def seeded_static_list(fn):
    # TS103: list-typed static_argnames (unhashable).
    return jax.jit(fn, static_argnames=["n", "mode"])


def _seeded_dot_kernel(x_ref, g_ref, o_ref):
    # TS104: dot inside a Pallas kernel without preferred_element_type.
    o_ref[...] = jnp.dot(x_ref[...], g_ref[...])


def seeded_launch(x, g):
    return pl.pallas_call(
        _seeded_dot_kernel,
        out_shape=jax.ShapeDtypeStruct(x.shape, x.dtype),
    )(x, g)


def seeded_bf16_accum(plane):
    # TS105: accumulation on a bf16 storage plane without upcast.
    lo = plane.astype(jnp.bfloat16)
    acc = lo + lo
    acc += lo
    return acc


def seeded_taint_through_helper(x):
    # TS101 via intra-module propagation: helper branches on the traced
    # argument the jitted root feeds it.
    return _helper_branches(x)


def _helper_branches(y):
    if y.mean() > 0.5:
        return y * 2
    return y


seeded_registered = jax.jit(seeded_taint_through_helper)
