# lint: disable-file=LD201,LD202,LD203
"""Suppressed twin of seeded_lock_discipline.py.  Never executed."""

import threading


class SupCounters:
    def __init__(self):
        self._lock = threading.Lock()
        self.hits = 0  # guarded-by: _lock
        self.misses = 0  # guarded-by: _lock
        self.table = {}  # guarded-by: _lock

    def seeded_unguarded_write(self):
        self.misses = 0

    def seeded_unguarded_rmw(self):
        self.hits += 1

    def seeded_unguarded_item_write(self):
        self.table["k"] = 0


class SupCacheAB:
    def __init__(self, owner=None):
        self._lock = threading.Lock()
        self.owner = owner if owner is not None else SupOwnerBA()

    def fetch(self):
        with self._lock:
            self.owner.admit()


class SupOwnerBA:
    def __init__(self):
        self._lock = threading.Lock()
        self.cache = SupCacheAB()

    def admit(self):
        with self._lock:
            pass

    def lookup(self):
        with self._lock:
            self.cache.fetch()
