"""Seeded lock-discipline violations (LD201–LD203).  Never executed."""

import threading


class SeededCounters:
    def __init__(self):
        self._lock = threading.Lock()
        self.hits = 0  # guarded-by: _lock
        self.misses = 0  # guarded-by: _lock
        self.table = {}  # guarded-by: _lock

    def guarded_ok(self):
        with self._lock:
            self.hits += 1
            self.table["k"] = self.hits

    def seeded_unguarded_write(self):
        # LD201: plain rebind outside the lock.
        self.misses = 0

    def seeded_unguarded_rmw(self):
        # LD202: lost-update increment outside the lock.
        self.hits += 1

    def seeded_unguarded_item_write(self):
        # LD202: container mutation outside the lock.
        self.table["k"] = 0

    def annotated_helper(self):  # holds-lock: _lock
        self.hits += 1  # OK: caller holds the lock by contract


class SeededCacheAB:
    """Takes its own lock, then calls into SeededOwnerBA -> ABBA."""

    def __init__(self, owner=None):
        self._lock = threading.Lock()
        self.owner = owner if owner is not None else SeededOwnerBA()

    def fetch(self):
        with self._lock:
            self.owner.admit()  # LD203: Cache._lock -> Owner._lock ...


class SeededOwnerBA:
    def __init__(self):
        self._lock = threading.Lock()
        self.cache = SeededCacheAB()

    def admit(self):
        with self._lock:
            pass

    def lookup(self):
        with self._lock:
            self.cache.fetch()  # ... while Owner._lock -> Cache._lock here
