"""Spectral 3-D correlation: exactness vs the direct operator, in every
mode, plus overlap-save streaming equivalence (paper Fig. 1C)."""

import jax.numpy as jnp
import numpy as np
import pytest
from _hypo import given, settings, st  # hypothesis, or deterministic fallback

from repro.core import fidelity as fid
from repro.core import spectral_conv as sc

TOL = 2e-4


def _rand(shape, rng, positive=False):
    x = rng.randn(*shape).astype(np.float32)
    return jnp.asarray(np.abs(x) if positive else x)


@pytest.mark.parametrize("mode", ["valid", "same", "full"])
def test_fft_matches_direct(mode, rng):
    x = _rand((2, 2, 18, 20, 12), rng)
    k = _rand((3, 2, 5, 8, 4), rng)
    a = sc.correlate3d_fft(x, k, mode=mode)
    b = sc.direct_correlate3d(x, k, mode=mode)
    assert a.shape == b.shape
    np.testing.assert_allclose(a, b, atol=TOL * float(jnp.max(jnp.abs(b))) + 1e-5)


@settings(max_examples=12, deadline=None)
@given(
    h=st.integers(6, 16),
    w=st.integers(6, 16),
    t=st.integers(4, 12),
    kh=st.integers(1, 5),
    kw=st.integers(1, 5),
    kt=st.integers(1, 4),
    c=st.integers(1, 3),
    o=st.integers(1, 3),
)
def test_fft_matches_direct_property(h, w, t, kh, kw, kt, c, o):
    rng = np.random.RandomState(h * 100 + w * 10 + t)
    x = _rand((1, c, h, w, t), rng)
    k = _rand((o, c, kh, kw, kt), rng)
    a = sc.correlate3d_fft(x, k, mode="valid")
    b = sc.direct_correlate3d(x, k, mode="valid")
    np.testing.assert_allclose(a, b, atol=TOL * float(jnp.max(jnp.abs(b))) + 1e-5)


@settings(max_examples=10, deadline=None)
@given(
    t=st.integers(8, 40),
    kt=st.integers(2, 5),
    extra=st.integers(1, 12),
)
def test_overlap_save_equals_one_shot(t, kt, extra):
    """Streaming (coherence-window) correlation ≡ one-shot correlation for
    every window size > kt−1 — the paper's segmentation is lossless.
    Runs through the engine's streaming driver (the one overlap-save
    path; spectral_conv holds only the windowing arithmetic)."""
    from repro.core.sthc import STHC, STHCConfig

    rng = np.random.RandomState(t * 7 + kt)
    x = _rand((1, 1, 10, 12, t), rng)
    k = _rand((2, 1, 3, 4, kt), rng)
    block_t = kt - 1 + extra
    ref = sc.direct_correlate3d(x, k, mode="valid")
    got = STHC(STHCConfig(fidelity=fid.ideal())).correlate_stream(k, x, block_t)
    np.testing.assert_allclose(got, ref, atol=TOL * float(jnp.max(jnp.abs(ref))) + 1e-5)


@settings(max_examples=15, deadline=None)
@given(
    t=st.integers(5, 80),
    kt=st.integers(2, 5),
    extra=st.integers(1, 12),
    chunk=st.integers(1, 6),
)
def test_stream_plan_arithmetic(t, kt, extra, chunk):
    """The pure windowing math: full coverage, whole chunks, minimal pad."""
    if t < kt:
        with pytest.raises(ValueError):
            sc.stream_plan(t, kt, kt - 1 + extra, chunk)
        return
    plan = sc.stream_plan(t, kt, kt - 1 + extra, chunk)
    assert plan.step == plan.block_t - kt + 1
    assert plan.n_valid == t - kt + 1
    # windows cover every valid output exactly once after cropping
    assert (plan.n_blocks - 1) * plan.step < plan.n_valid <= plan.n_blocks * plan.step
    assert plan.n_padded % plan.chunk == 0 and plan.n_padded >= plan.n_blocks
    assert plan.n_padded - plan.n_blocks < plan.chunk
    # padded stream is exactly long enough for the last window
    assert (plan.n_padded - 1) * plan.step + plan.block_t == t + plan.pad_t
    starts = np.asarray(sc.window_starts(plan))
    assert starts.shape == (plan.n_padded // plan.chunk, plan.chunk)
    assert starts.flatten()[-1] == (plan.n_padded - 1) * plan.step


def test_stream_plan_rejects_short_window():
    with pytest.raises(ValueError, match="block_t"):
        sc.stream_plan(20, 4, 3)


def test_grating_reuse(rng):
    """Recording once and querying many times is the weight-stationary
    dataflow — identical results for every query."""
    k = _rand((2, 1, 5, 6, 3), rng)
    sig = (16, 18, 10)
    fft_shape = sc.fft_shape_for(sig, k.shape[-3:])
    grating = sc.make_grating(k, fft_shape)
    out_shape = sc.valid_shape(sig, k.shape[-3:])
    for i in range(3):
        x = _rand((1, 1) + sig, np.random.RandomState(i))
        a = sc.query_grating(x, grating, fft_shape, out_shape)
        b = sc.direct_correlate3d(x, k, mode="valid")
        np.testing.assert_allclose(a, b, atol=TOL * float(jnp.max(jnp.abs(b))) + 1e-5)


def test_next_fast_len():
    for n in [1, 2, 3, 17, 97, 100, 129, 1000]:
        m = sc.next_fast_len(n)
        assert m >= n
        # 5-smooth check
        x = m
        for p in (2, 3, 5):
            while x % p == 0:
                x //= p
        assert x == 1, (n, m)


def test_spectral_flops_advantage():
    """The paper's large-kernel workload must favor the spectral path."""
    from repro.core.throughput import ConvWorkload

    wl = ConvWorkload()  # 30×40×8 kernels on 60×80×16 clips
    assert wl.spectral_advantage() > 5.0, wl.spectral_advantage()
