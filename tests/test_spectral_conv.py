"""Spectral 3-D correlation: exactness vs the direct operator, in every
mode, plus overlap-save streaming equivalence (paper Fig. 1C)."""

import jax.numpy as jnp
import numpy as np
import pytest
from _hypo import given, settings, st  # hypothesis, or deterministic fallback

from repro.core import fidelity as fid
from repro.core import spectral_conv as sc

TOL = 2e-4


def _rand(shape, rng, positive=False):
    x = rng.randn(*shape).astype(np.float32)
    return jnp.asarray(np.abs(x) if positive else x)


@pytest.mark.parametrize("mode", ["valid", "same", "full"])
def test_fft_matches_direct(mode, rng):
    x = _rand((2, 2, 18, 20, 12), rng)
    k = _rand((3, 2, 5, 8, 4), rng)
    a = sc.correlate3d_fft(x, k, mode=mode)
    b = sc.direct_correlate3d(x, k, mode=mode)
    assert a.shape == b.shape
    np.testing.assert_allclose(a, b, atol=TOL * float(jnp.max(jnp.abs(b))) + 1e-5)


@settings(max_examples=12, deadline=None)
@given(
    h=st.integers(6, 16),
    w=st.integers(6, 16),
    t=st.integers(4, 12),
    kh=st.integers(1, 5),
    kw=st.integers(1, 5),
    kt=st.integers(1, 4),
    c=st.integers(1, 3),
    o=st.integers(1, 3),
)
def test_fft_matches_direct_property(h, w, t, kh, kw, kt, c, o):
    rng = np.random.RandomState(h * 100 + w * 10 + t)
    x = _rand((1, c, h, w, t), rng)
    k = _rand((o, c, kh, kw, kt), rng)
    a = sc.correlate3d_fft(x, k, mode="valid")
    b = sc.direct_correlate3d(x, k, mode="valid")
    np.testing.assert_allclose(a, b, atol=TOL * float(jnp.max(jnp.abs(b))) + 1e-5)


@settings(max_examples=10, deadline=None)
@given(
    t=st.integers(8, 40),
    kt=st.integers(2, 5),
    extra=st.integers(1, 12),
)
def test_overlap_save_equals_one_shot(t, kt, extra):
    """Streaming (coherence-window) correlation ≡ one-shot correlation for
    every window size > kt−1 — the paper's segmentation is lossless.
    Runs through the engine's streaming driver (the one overlap-save
    path; spectral_conv holds only the windowing arithmetic)."""
    from repro.core.sthc import STHC, STHCConfig

    rng = np.random.RandomState(t * 7 + kt)
    x = _rand((1, 1, 10, 12, t), rng)
    k = _rand((2, 1, 3, 4, kt), rng)
    block_t = kt - 1 + extra
    ref = sc.direct_correlate3d(x, k, mode="valid")
    got = STHC(STHCConfig(fidelity=fid.ideal())).correlate_stream(k, x, block_t)
    np.testing.assert_allclose(got, ref, atol=TOL * float(jnp.max(jnp.abs(ref))) + 1e-5)


@settings(max_examples=15, deadline=None)
@given(
    t=st.integers(5, 80),
    kt=st.integers(2, 5),
    extra=st.integers(1, 12),
    chunk=st.integers(1, 6),
)
def test_stream_plan_arithmetic(t, kt, extra, chunk):
    """The pure windowing math: full coverage, whole chunks, minimal pad."""
    if t < kt:
        with pytest.raises(ValueError):
            sc.stream_plan(t, kt, kt - 1 + extra, chunk)
        return
    plan = sc.stream_plan(t, kt, kt - 1 + extra, chunk)
    assert plan.step == plan.block_t - kt + 1
    assert plan.n_valid == t - kt + 1
    # windows cover every valid output exactly once after cropping
    assert (plan.n_blocks - 1) * plan.step < plan.n_valid <= plan.n_blocks * plan.step
    assert plan.n_padded % plan.chunk == 0 and plan.n_padded >= plan.n_blocks
    assert plan.n_padded - plan.n_blocks < plan.chunk
    # padded stream is exactly long enough for the last window
    assert (plan.n_padded - 1) * plan.step + plan.block_t == t + plan.pad_t
    starts = np.asarray(sc.window_starts(plan))
    assert starts.shape == (plan.n_padded // plan.chunk, plan.chunk)
    assert starts.flatten()[-1] == (plan.n_padded - 1) * plan.step


def test_stream_plan_rejects_short_window():
    with pytest.raises(ValueError, match="block_t"):
        sc.stream_plan(20, 4, 3)


def test_grating_reuse(rng):
    """Recording once and querying many times is the weight-stationary
    dataflow — identical results for every query."""
    k = _rand((2, 1, 5, 6, 3), rng)
    sig = (16, 18, 10)
    fft_shape = sc.fft_shape_for(sig, k.shape[-3:])
    grating = sc.make_grating(k, fft_shape)
    out_shape = sc.valid_shape(sig, k.shape[-3:])
    for i in range(3):
        x = _rand((1, 1) + sig, np.random.RandomState(i))
        a = sc.query_grating(x, grating, fft_shape, out_shape)
        b = sc.direct_correlate3d(x, k, mode="valid")
        np.testing.assert_allclose(a, b, atol=TOL * float(jnp.max(jnp.abs(b))) + 1e-5)


def test_next_fast_len():
    for n in [1, 2, 3, 17, 97, 100, 129, 1000]:
        m = sc.next_fast_len(n)
        assert m >= n
        # 5-smooth check
        x = m
        for p in (2, 3, 5):
            while x % p == 0:
                x //= p
        assert x == 1, (n, m)


def test_spectral_flops_advantage():
    """The paper's large-kernel workload must favor the spectral path."""
    from repro.core.throughput import ConvWorkload

    wl = ConvWorkload()  # 30×40×8 kernels on 60×80×16 clips
    assert wl.spectral_advantage() > 5.0, wl.spectral_advantage()


# -- bounded-memory stream cursor (pure windowing arithmetic) -----------------


@settings(max_examples=16, deadline=None)
@given(
    t=st.integers(8, 90),
    kt=st.integers(2, 6),
    extra=st.integers(1, 9),
    mbw=st.integers(1, 7),
)
def test_stream_cursor_partitions_windows(t, kt, extra, mbw):
    """Cursor segments partition the plan's windows and valid outputs
    exactly: window counts sum to n_blocks, per-segment valid outputs
    tile [0, n_valid) contiguously and disjointly, and consecutive
    segments overlap by exactly kt−1 input frames (the carry-over
    tail)."""
    if t < kt:
        t = kt + t
    block_t = kt - 1 + extra
    cursor = sc.stream_cursor(t, kt, block_t, max_buffer_windows=mbw)
    plan = cursor.plan
    segs = list(cursor)
    assert sum(s.n_windows for s in segs) == plan.n_blocks
    assert segs[0].t0 == 0 and segs[0].out_t0 == 0
    out_next = 0
    for i, s in enumerate(segs):
        assert s.n_windows <= mbw
        assert s.out_t0 == out_next
        out_next += s.n_valid
        assert s.frames == s.t1 - s.t0 <= cursor.peak_buffer_frames
        if i > 0:
            prev = segs[i - 1]
            # segment input ranges overlap by the carry-over tail: the
            # next segment re-reads the kt−1 frames that straddle the
            # boundary windows (clipped at the stream tail)
            assert s.t0 == prev.t0 + prev.n_windows * plan.step
            assert prev.t1 - s.t0 == kt - 1  # exactly the carry-over
    assert out_next == plan.n_valid
    assert segs[-1].t1 <= t
    # the constant-memory bound: every segment fits the fixed buffer
    bound = (min(mbw, plan.n_blocks) - 1) * plan.step + plan.block_t
    assert cursor.peak_buffer_frames <= bound


def test_stream_cursor_single_segment_when_unbounded():
    cursor = sc.stream_cursor(40, 3, 10, max_buffer_windows=None)
    assert len(cursor) == 1
    (seg,) = cursor
    assert seg.t0 == 0 and seg.n_windows == cursor.plan.n_blocks
    assert seg.n_valid == cursor.plan.n_valid


def test_stream_cursor_rejects_bad_budget():
    plan = sc.stream_plan(40, 3, 10)
    with pytest.raises(ValueError, match="max_buffer_windows"):
        sc.StreamCursor(plan, 0)


def test_stream_cursor_segment_plans_are_consistent():
    """Each segment re-planned at its own frame count yields exactly its
    window/valid counts — the invariant the engine's chunked driver
    relies on (segment sub-plans never disagree with the cursor)."""
    cursor = sc.stream_cursor(67, 4, 12, chunk_windows=2, max_buffer_windows=3)
    for seg in cursor:
        sub = sc.stream_plan(seg.frames, 4, 12, 2)
        assert sub.n_blocks == seg.n_windows
        assert sub.n_valid == seg.n_valid
