"""Data substrates: synthetic KTH geometry/splits/determinism and the
deterministic LM token stream (fault-tolerance contract)."""

import numpy as np
from _hypo import given, settings, st  # hypothesis, or deterministic fallback

from repro.data import kth_synthetic as kth
from repro.data import tokens as tok


def test_kth_shapes_and_splits():
    xs, ys = kth.make_split("val")
    assert xs.shape == (64, 1, 60, 80, 16)  # 4 subjects × 4 scen × 4 classes
    assert xs.dtype == np.float32
    assert xs.min() >= 0.0 and xs.max() <= 1.0
    assert sorted(np.unique(ys)) == [0, 1, 2, 3]
    counts = np.bincount(ys)
    assert (counts == 16).all()


def test_kth_split_sizes_match_paper():
    # paper §4.1: 192 train / 64 val / 144 test
    assert len(kth.make_split("train")[1]) == 192
    assert len(kth.make_split("val")[1]) == 64
    assert len(kth.make_split("test")[1]) == 144


def test_kth_deterministic():
    a = kth.render_clip(2, subject=5, scenario=1)
    b = kth.render_clip(2, subject=5, scenario=1)
    np.testing.assert_array_equal(a, b)
    c = kth.render_clip(2, subject=6, scenario=1)
    assert np.abs(a - c).max() > 1e-3  # subjects differ


def test_kth_classes_are_motion_separable():
    """Running (global translation) must show far larger spatial-centroid
    drift than the stationary upper-body classes — the classes differ in
    *dynamics*, not single-frame appearance."""

    def centroid_drift(v):
        h, w, T = v.shape
        xs = np.arange(w)[None, :, None]
        I = v - v.min()
        cx = (I * xs).sum((0, 1)) / I.sum((0, 1))
        return float(np.std(cx))

    run = centroid_drift(kth.render_clip(3, 1, 0))
    others = [centroid_drift(kth.render_clip(l, 1, 0)) for l in (0, 1, 2)]
    assert run > 3 * max(others), (run, others)


@settings(max_examples=10, deadline=None)
@given(step=st.integers(0, 10_000), shard=st.integers(0, 3))
def test_token_stream_pure_function(step, shard):
    cfg = tok.TokenStreamConfig(vocab=128, seq_len=32)
    a = tok.batch_at_step(cfg, step, 8, shard=shard, num_shards=4)
    b = tok.batch_at_step(cfg, step, 8, shard=shard, num_shards=4)
    np.testing.assert_array_equal(a["tokens"], b["tokens"])
    np.testing.assert_array_equal(a["labels"], b["labels"])
    assert a["tokens"].shape == (2, 32)
    # labels are next-token shifted
    full_a = tok.batch_at_step(cfg, step, 8, shard=shard, num_shards=4)
    np.testing.assert_array_equal(a["labels"][:, :-1], full_a["tokens"][:, 1:])


def test_token_stream_has_learnable_structure():
    """The k-gram rules make the stream compressible below unigram entropy
    — a bigram table must beat the unigram baseline."""
    cfg = tok.TokenStreamConfig(vocab=64, seq_len=256, rule_frac=0.8)
    batches = [tok.batch_at_step(cfg, s, 16) for s in range(4)]
    toks = np.concatenate([b["tokens"].reshape(-1) for b in batches])
    # unigram entropy
    p = np.bincount(toks, minlength=64) / len(toks)
    h1 = -np.sum(p[p > 0] * np.log(p[p > 0]))
    # order-3 conditional entropy estimate
    ctx = {}
    seqs = np.concatenate([b["tokens"] for b in batches], 0)
    for row in seqs:
        for t in range(3, len(row)):
            key = tuple(row[t - 3 : t])
            ctx.setdefault(key, []).append(row[t])
    h3_num, n = 0.0, 0
    for key, nxt in ctx.items():
        if len(nxt) < 2:
            continue
        q = np.bincount(nxt, minlength=64) / len(nxt)
        h3_num += -np.sum(q[q > 0] * np.log(q[q > 0])) * len(nxt)
        n += len(nxt)
    h3 = h3_num / max(n, 1)
    assert h3 < 0.8 * h1, (h1, h3)
