"""Optimizer substrate: AdamW behavior, schedules, gradient compression
with error feedback (convergence parity)."""

import jax
import jax.numpy as jnp
import numpy as np
from _hypo import given, settings, st  # hypothesis, or deterministic fallback

from repro.optim import (
    AdamWConfig,
    adamw_init,
    adamw_update,
    compress_gradients,
    compression_init,
    cosine_schedule,
    global_norm,
)
from repro.optim.compression import _quantize_leaf


def _quadratic_problem(dim=16, seed=0):
    rng = np.random.RandomState(seed)
    target = jnp.asarray(rng.randn(dim).astype(np.float32))

    def loss(p):
        return jnp.sum((p["w"] - target) ** 2)

    params = {"w": jnp.zeros(dim)}
    return loss, params, target


def test_adamw_converges_quadratic():
    loss, params, target = _quadratic_problem()
    cfg = AdamWConfig(lr=5e-2, weight_decay=0.0, clip_norm=1e9)
    state = adamw_init(cfg, params)
    for _ in range(300):
        g = jax.grad(loss)(params)
        params, state, _ = adamw_update(cfg, params, g, state)
    assert float(loss(params)) < 1e-2


def test_clip_norm_applied():
    cfg = AdamWConfig(clip_norm=1.0)
    params = {"w": jnp.zeros(4)}
    state = adamw_init(cfg, params)
    g = {"w": jnp.full((4,), 100.0)}
    _, _, metrics = adamw_update(cfg, params, g, state)
    assert float(metrics["grad_norm"]) > 1.0
    assert float(metrics["clip_scale"]) < 1.0


def test_bf16_state_dtype():
    cfg = AdamWConfig(state_dtype=jnp.bfloat16)
    params = {"w": jnp.zeros(4)}
    state = adamw_init(cfg, params)
    assert state["m"]["w"].dtype == jnp.bfloat16
    g = {"w": jnp.ones(4)}
    p2, s2, _ = adamw_update(cfg, params, g, state)
    assert s2["v"]["w"].dtype == jnp.bfloat16
    assert bool(jnp.all(jnp.isfinite(p2["w"])))


def test_cosine_schedule_shape():
    assert float(cosine_schedule(0, 100, warmup_steps=10)) < 0.2
    assert abs(float(cosine_schedule(10, 100, warmup_steps=10)) - 1.0) < 0.05
    assert float(cosine_schedule(99, 100, warmup_steps=10)) < 0.2


# -- compression ---------------------------------------------------------


@settings(max_examples=15, deadline=None)
@given(seed=st.integers(0, 1000), block=st.sampled_from([32, 256]))
def test_quantizer_bounded_error(seed, block):
    rng = np.random.RandomState(seed)
    g = jnp.asarray(rng.randn(300).astype(np.float32) * 10)
    q = _quantize_leaf(g, block)
    # error bounded by half a quantization step per block
    step = jnp.max(jnp.abs(g)) / 127.0
    assert float(jnp.max(jnp.abs(q - g))) <= float(step) + 1e-5


def test_error_feedback_accumulates_residual():
    g = {"w": jnp.asarray([1e-4, 5.0, -3.0, 1e-5])}
    err = compression_init(g)
    comp, err = compress_gradients(g, err)
    # residual = what quantization lost
    np.testing.assert_allclose(
        np.asarray(comp["w"] + err["w"]), np.asarray(g["w"]), atol=1e-6
    )


def test_compression_convergence_parity():
    """int8+EF compression must not break optimization: final loss within
    2× of the uncompressed run on the quadratic."""
    loss, params, _ = _quadratic_problem(seed=3)
    cfg = AdamWConfig(lr=5e-2, weight_decay=0.0, clip_norm=1e9)

    def run(compressed: bool) -> float:
        p = {"w": jnp.zeros(16)}
        state = adamw_init(cfg, p)
        err = compression_init(p)
        for _ in range(300):
            g = jax.grad(loss)(p)
            if compressed:
                g, err = compress_gradients(g, err)
            p, state, _ = adamw_update(cfg, p, g, state)
        return float(loss(p))

    plain, comp = run(False), run(True)
    assert comp < max(2 * plain, 5e-2), (plain, comp)


def test_global_norm():
    t = {"a": jnp.asarray([3.0]), "b": jnp.asarray([4.0])}
    assert abs(float(global_norm(t)) - 5.0) < 1e-6
