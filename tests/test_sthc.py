"""STHC correlator: ideal-mode exactness, physical-mode graceful
degradation, pseudo-negative encoding, atomic-physics envelopes,
coherence-window segmentation."""

import jax.numpy as jnp
import numpy as np
import pytest
from _hypo import given, settings, st  # hypothesis, or deterministic fallback

from repro.core import atomic, optics, pseudo_negative, spectral_conv as sc
from repro.core import fidelity as fid
from repro.core.sthc import STHC, STHCConfig


def _data(rng, B=2, C=1, H=20, W=24, T=10, O=3, kh=7, kw=9, kt=4):
    x = jnp.asarray(rng.rand(B, C, H, W, T).astype(np.float32))
    k = jnp.asarray(rng.randn(O, C, kh, kw, kt).astype(np.float32))
    return x, k


def test_ideal_mode_is_exact(rng):
    x, k = _data(rng)
    y = STHC(STHCConfig(fidelity=fid.ideal()))(k, x)
    ref = sc.direct_correlate3d(x, k, "valid")
    np.testing.assert_allclose(y, ref, atol=1e-4 * float(jnp.max(jnp.abs(ref))))


def test_ideal_mode_pallas_path(rng):
    x, k = _data(rng)
    y = STHC(STHCConfig(fidelity=fid.ideal(), use_pallas=True))(k, x)
    ref = sc.direct_correlate3d(x, k, "valid")
    np.testing.assert_allclose(y, ref, atol=1e-4 * float(jnp.max(jnp.abs(ref))))


def test_physical_mode_bounded_error(rng):
    x, k = _data(rng)
    ref = sc.direct_correlate3d(x, k, "valid")
    y = STHC(STHCConfig(fidelity=fid.physical()))(k, x)
    rel = float(jnp.linalg.norm(y - ref) / jnp.linalg.norm(ref))
    assert rel < 0.10, rel  # design-point physics ⇒ small degradation


def test_physical_error_monotone_in_coverage(rng):
    """More IHB coverage ⇒ closer to ideal (the design regime)."""
    x, k = _data(rng)
    ref = sc.direct_correlate3d(x, k, "valid")
    errs = []
    for cov in (1.0, 2.0, 4.0, 8.0):
        s = STHC(STHCConfig(fidelity=fid.physical(), atoms=atomic.AtomicConfig(coverage=cov)))
        y = s(k, x)
        errs.append(float(jnp.linalg.norm(y - ref) / jnp.linalg.norm(ref)))
    assert errs == sorted(errs, reverse=True), errs


def test_short_t2_degrades(rng):
    x, k = _data(rng)
    ref = sc.direct_correlate3d(x, k, "valid")
    good = STHC(STHCConfig(fidelity=fid.physical()))(k, x)
    bad = STHC(
        STHCConfig(
            fidelity=fid.physical(),
            atoms=atomic.AtomicConfig(t2_s=3 * atomic.FRAME_LOAD_TIME_S),
        )
    )(k, x)
    e = lambda y: float(jnp.linalg.norm(y - ref) / jnp.linalg.norm(ref))
    assert e(bad) > 3 * e(good)


def test_pulse_compensation_reduces_error(rng):
    """Regression for the compensate_pulse no-op: the recording-pulse
    spectrum is burned into the grating, and compensation must divide it
    back out — so the compensated correlator is strictly closer to the
    direct reference, at every IHB coverage.  (The seed computed
    ``h·p/max(p,1e-3)`` under *both* settings, making the flag a no-op.)"""
    x, k = _data(rng)
    ref = sc.direct_correlate3d(x, k, "valid")
    e = lambda y: float(jnp.linalg.norm(y - ref) / jnp.linalg.norm(ref))
    for cov in (1.0, 2.0, 4.0):
        atoms = atomic.AtomicConfig(coverage=cov)
        err_comp = e(
            STHC(STHCConfig(fidelity=fid.physical(), atoms=atoms))(k, x)
        )
        err_unc = e(
            STHC(
                STHCConfig(
                    fidelity=fid.physical(compensate_pulse=False), atoms=atoms
                )
            )(k, x)
        )
        # materially different (the flag does something) and correctly ordered
        assert err_comp < 0.9 * err_unc, (cov, err_comp, err_unc)


# -- pseudo-negative encoding ------------------------------------------------


@settings(max_examples=15, deadline=None)
@given(seed=st.integers(0, 10_000))
def test_pseudo_negative_identity(seed):
    """(X ⋆ K⁺) − (X ⋆ K⁻) ≡ X ⋆ K exactly (linearity of correlation)."""
    rng = np.random.RandomState(seed)
    x = jnp.asarray(rng.rand(1, 1, 12, 12, 8).astype(np.float32))
    k = jnp.asarray(rng.randn(2, 1, 4, 5, 3).astype(np.float32))
    kp, km = pseudo_negative.split(k)
    assert float(jnp.min(kp)) >= 0 and float(jnp.min(km)) >= 0
    np.testing.assert_allclose(kp - km, k, atol=1e-7)
    yp = sc.direct_correlate3d(x, kp, "valid")
    ym = sc.direct_correlate3d(x, km, "valid")
    ref = sc.direct_correlate3d(x, k, "valid")
    np.testing.assert_allclose(
        pseudo_negative.combine(yp, ym), ref,
        atol=2e-4 * float(jnp.max(jnp.abs(ref))) + 1e-6,
    )


def test_interleave_roundtrip(rng):
    k = jnp.asarray(rng.randn(3, 2, 4, 4, 2).astype(np.float32))
    kp, km = pseudo_negative.split(k)
    inter = pseudo_negative.interleave_channels(kp, km)
    assert inter.shape[0] == 6
    y = jnp.asarray(rng.randn(2, 6, 5, 5, 3).astype(np.float32))
    signed = pseudo_negative.deinterleave_outputs(y)
    ref = y[:, 0::2] - y[:, 1::2]
    np.testing.assert_allclose(signed, ref, atol=1e-6)


# -- optics / atomic models ---------------------------------------------------


def test_slm_quantization_error_scales_with_bits(rng):
    x = jnp.asarray(rng.rand(8, 8).astype(np.float32))
    errs = [
        float(jnp.max(jnp.abs(optics.quantize_unit(x, b) - x))) for b in (4, 8, 12)
    ]
    assert errs[0] > errs[1] > errs[2]
    assert errs[1] <= 1.0 / 255 + 1e-6


def test_recording_pulse_is_flat(rng):
    spec = optics.recording_pulse_spectrum((64, 64), radius_px=1.5)
    # small disc ⇒ near-flat spatial spectrum over the *signal* band
    # (the Airy rolloff lives at high frequencies, outside the video band)
    central = jnp.fft.fftshift(spec)[24:40, 24:40]  # |f| ≤ Nyquist/4
    assert float(jnp.min(central)) > 0.8
    assert abs(float(jnp.max(spec)) - 1.0) < 1e-6  # unit peak at DC


def test_ihb_envelope_unit_peak_and_symmetry():
    env = atomic.ihb_envelope(16, atomic.AtomicConfig())
    assert abs(float(jnp.max(env)) - 1.0) < 1e-6
    np.testing.assert_allclose(env[1:9], env[-1:-9:-1][::1], atol=1e-6)


def test_t2_tap_weights_design_regime():
    w = atomic.t2_tap_weights(8, atomic.AtomicConfig())
    assert float(jnp.min(w)) > 0.999  # ms T2, ns frames ⇒ ≈ 1
    short = atomic.t2_tap_weights(
        8, atomic.AtomicConfig(t2_s=4 * atomic.FRAME_LOAD_TIME_S)
    )
    assert float(short[0]) < float(short[-1])  # earlier taps decay more


def test_echo_time():
    assert atomic.echo_time(1.0, 3.0, 7.0) == 9.0


# -- segmentation (paper Fig. 1C) ---------------------------------------------


@settings(max_examples=25, deadline=None)
@given(
    total=st.integers(10, 500),
    window=st.integers(4, 64),
    query=st.integers(1, 20),
)
def test_segmentation_covers_every_query_position(total, window, query):
    """Every query-length interval must fit inside some window — the
    overlap-by-T1 property that makes boundary events detectable."""
    if window <= query:
        with pytest.raises(ValueError):
            atomic.segment_database(total, window, query)
        return
    segs = atomic.segment_database(total, window, query)
    assert segs[0][0] == 0 and segs[-1][1] >= min(total, segs[-1][1])
    for start in range(0, max(total - query, 0) + 1):
        assert any(s <= start and start + query <= e for s, e in segs), (
            start,
            segs,
        )
