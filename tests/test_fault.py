"""Fault tolerance: kill/restart bitwise continuation, restart driver,
deterministic shard reassignment, elastic re-mesh (subprocess)."""

import os
import subprocess
import sys

import jax
import numpy as np
import pytest
from _hypo import given, settings, st  # hypothesis, or deterministic fallback

from repro import configs
from repro.distributed.fault import (
    FailureInjector,
    SimulatedFailure,
    reassign_shards,
    run_with_restarts,
)
from repro.launch.train import TrainConfig, train_loop
from repro.optim import AdamWConfig

CFG_KW = dict(steps=12, batch=4, seq=16, save_every=4, async_ckpt=False)


def _final_params(ckpt_dir, failure=None):
    cfg = configs.get_smoke_config("qwen2-1.5b")
    tc = TrainConfig(**CFG_KW)

    def run():
        return train_loop(
            cfg, tc, ckpt_dir, opt_cfg=AdamWConfig(lr=1e-3),
            failure=failure, log=lambda *_: None,
        )

    return run_with_restarts(run)


def test_restart_bitwise_identical(tmp_path):
    """Training killed at steps 5 and 9 then restarted must produce
    exactly the same final parameters as an uninterrupted run."""
    clean = _final_params(str(tmp_path / "clean"))
    faulty = _final_params(
        str(tmp_path / "faulty"), FailureInjector(fail_at_steps=(5, 9))
    )
    assert clean["steps_done"] == faulty["steps_done"]
    for a, b in zip(
        jax.tree.leaves(clean["params"]), jax.tree.leaves(faulty["params"])
    ):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_injector_fires_once_per_step():
    inj = FailureInjector(fail_at_steps=(3,))
    inj.check(2)
    with pytest.raises(SimulatedFailure):
        inj.check(3)
    inj.check(3)  # restart passes the same step


def test_run_with_restarts_gives_up():
    calls = {"n": 0}

    def always_fails():
        calls["n"] += 1
        raise SimulatedFailure("boom")

    with pytest.raises(SimulatedFailure):
        run_with_restarts(always_fails, max_restarts=3)
    assert calls["n"] == 4


@settings(max_examples=20, deadline=None)
@given(
    num_shards=st.integers(1, 64),
    dead=st.sets(st.integers(0, 7), max_size=7),
)
def test_reassign_shards_total_and_deterministic(num_shards, dead):
    live = [w for w in range(8) if w not in dead]
    if not live:
        with pytest.raises(ValueError):
            reassign_shards(num_shards, live)
        return
    a = reassign_shards(num_shards, live)
    b = reassign_shards(num_shards, list(reversed(live)))
    assert a == b  # order-independent (coordination-free)
    got = sorted(s for shards in a.values() for s in shards)
    assert got == list(range(num_shards))  # every shard owned exactly once
    sizes = [len(v) for v in a.values()]
    assert max(sizes) - min(sizes) <= 1  # balanced


ELASTIC_SCRIPT = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=4"
import jax, numpy as np
import jax.numpy as jnp
from repro import configs
from repro.checkpoint import restore_resharded, save, latest_step
from repro.distributed import sharding as shd
from repro.launch import mesh as mesh_lib
from repro.models import model_api

cfg = configs.get_smoke_config("granite-8b")
mod = model_api.get_model(cfg)
params, axes = mod.init_params(cfg, jax.random.PRNGKey(0))
ckpt = os.environ["CKPT_DIR"]
save(ckpt, 1, {"params": params})

# resume onto a 2x2 mesh (different from the single-device origin)
mesh = mesh_lib.make_local_mesh(2, 2)
rules = shd.make_rules("train")
sh = shd.tree_shardings(params, axes, rules, mesh)
out = restore_resharded(ckpt, 1, {"params": params}, {"params": sh})
p2 = out["params"]
for a, b in zip(jax.tree.leaves(params), jax.tree.leaves(p2)):
    np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
# verify actually sharded
leaf = p2["layers"]["w_up"]
assert len(leaf.sharding.device_set) == 4, leaf.sharding
print("ELASTIC_OK")
"""


def test_elastic_reshard_subprocess(tmp_path):
    """A checkpoint written on one topology restores bit-identically onto
    a 2×2 mesh (4 host devices) — the elastic-scaling path."""
    env = dict(os.environ, CKPT_DIR=str(tmp_path), PYTHONPATH="src")
    proc = subprocess.run(
        [sys.executable, "-c", ELASTIC_SCRIPT],
        capture_output=True, text=True, env=env, cwd=os.getcwd(), timeout=300,
    )
    assert "ELASTIC_OK" in proc.stdout, proc.stderr[-2000:]


# -- minimal-movement reassignment + heartbeat membership -------------------


@settings(max_examples=30, deadline=None)
@given(
    num_shards=st.integers(1, 64),
    dead=st.sets(st.integers(0, 7), max_size=6),
)
def test_reassign_shards_minimal_movement_on_death(num_shards, dead):
    """Killing workers moves ONLY the dead workers' shards: every shard
    of a surviving worker stays exactly where it was, orphans land on
    the least-loaded survivors, and the result stays near-balanced."""
    workers = list(range(8))
    before = reassign_shards(num_shards, workers)
    live = [w for w in workers if w not in dead]
    if not live:
        return
    after = reassign_shards(num_shards, live, previous=before)
    # totality: every shard owned exactly once
    got = sorted(s for shards in after.values() for s in shards)
    assert got == list(range(num_shards))
    # minimal movement: survivors keep their shards
    for w in live:
        assert set(before[w]) <= set(after[w])
    moved = sum(len(after[w]) - len(before[w]) for w in live)
    orphaned = sum(len(before[w]) for w in dead)
    assert moved == orphaned
    # balance from a balanced start: greedy least-loaded placement keeps
    # the spread within one shard
    sizes = [len(v) for v in after.values()]
    assert max(sizes) - min(sizes) <= 1


@settings(max_examples=30, deadline=None)
@given(num_shards=st.integers(1, 64), joiners=st.integers(1, 4))
def test_reassign_shards_join_moves_nothing(num_shards, joiners):
    """A worker JOINING moves zero shards (stability beats rebalance:
    moving a shard re-records its gratings) and reassignment with an
    unchanged membership is idempotent."""
    workers = list(range(6))
    before = reassign_shards(num_shards, workers)
    grown = workers + [100 + j for j in range(joiners)]
    after = reassign_shards(num_shards, grown, previous=before)
    for w in workers:
        assert after[w] == before[w]
    for j in range(joiners):
        assert after[100 + j] == []
    assert reassign_shards(num_shards, workers, previous=before) == before


def test_heartbeat_lifecycle_fake_clock():
    """healthy → suspect → dead under staleness; a beat from suspect
    flaps back to healthy; dead is sticky until re-registration."""
    from repro.distributed.fault import (
        DEAD,
        HEALTHY,
        SUSPECT,
        HeartbeatMonitor,
    )

    class Clock:
        t = 0.0

        def __call__(self):
            return self.t

    clock = Clock()
    events = []
    mon = HeartbeatMonitor(
        suspect_after_s=1.0,
        dead_after_s=3.0,
        clock=clock,
        on_change=lambda m, old, new: events.append((m, old, new)),
    )
    mon.register("a")
    mon.register("b")
    assert mon.poll() == [] and mon.states() == {"a": HEALTHY, "b": HEALTHY}

    clock.t = 1.5  # past suspect, before dead
    mon.beat("b")
    assert mon.poll() == [("a", HEALTHY, SUSPECT)]
    assert mon.state("b") == HEALTHY

    clock.t = 2.0  # a beat from suspect recovers (a flap, counted)
    mon.beat("a")
    assert mon.state("a") == HEALTHY and mon.flaps == 1
    assert ("a", SUSPECT, HEALTHY) in events

    clock.t = 5.5  # a: stale 3.5s -> dead (skipping suspect); b: 4.0 -> dead
    changes = mon.poll()
    assert set(changes) == {("a", HEALTHY, DEAD), ("b", HEALTHY, DEAD)}
    assert mon.deaths == 2

    mon.beat("a")  # dead is sticky: beats dropped
    assert mon.state("a") == DEAD
    assert mon.members(HEALTHY) == []

    mon.register("a")  # replacement re-admits under the same id
    assert mon.state("a") == HEALTHY
    assert mon.members(HEALTHY, DEAD) == ["a", "b"]


def test_heartbeat_draining_and_mark_validation():
    from repro.distributed.fault import (
        DEAD,
        DRAINING,
        HEALTHY,
        HeartbeatMonitor,
    )

    mon = HeartbeatMonitor(suspect_after_s=10.0, dead_after_s=20.0)
    mon.register("a")
    mon.mark("a", DRAINING)
    assert mon.state("a") == DRAINING
    assert mon.members(HEALTHY) == []  # no new work while draining
    mon.mark("a", DEAD)
    assert mon.deaths == 1
    with pytest.raises(ValueError):
        mon.mark("a", "zombie")
    with pytest.raises(ValueError):
        HeartbeatMonitor(suspect_after_s=2.0, dead_after_s=1.0)
