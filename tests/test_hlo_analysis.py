"""The trip-count-aware HLO analyzer: validated against a compiled scan
program with known FLOP/collective ground truth (single CPU device)."""

import jax
import jax.numpy as jnp
import numpy as np

from repro.launch import hlo_analysis as H


def _compile(fn, *args):
    return jax.jit(fn).lower(*args).compile().as_text()


def test_dot_flops_exact():
    a = jax.ShapeDtypeStruct((64, 128), jnp.float32)
    b = jax.ShapeDtypeStruct((128, 32), jnp.float32)
    hlo = _compile(lambda x, y: x @ y, a, b)
    got = H.analyze_hlo(hlo).op_flops.get("dot", 0)
    assert got == 2 * 64 * 32 * 128, got


def test_scan_trip_count_multiplies():
    """A 7-iteration scan of a matmul must report 7× the single-dot FLOPs
    (the exact failure mode of XLA's own cost_analysis)."""
    w = jax.ShapeDtypeStruct((7, 32, 32), jnp.float32)
    x = jax.ShapeDtypeStruct((8, 32), jnp.float32)

    def f(w, x):
        def body(c, wl):
            return jnp.tanh(c @ wl), None

        y, _ = jax.lax.scan(body, x, w)
        return y

    hlo = _compile(f, w, x)
    got = H.analyze_hlo(hlo).op_flops.get("dot", 0)
    assert got == 7 * 2 * 8 * 32 * 32, got


def test_fft_counted():
    x = jax.ShapeDtypeStruct((64,), jnp.complex64)
    hlo = _compile(jnp.fft.fft, x)
    a = H.analyze_hlo(hlo)
    assert a.op_flops.get("fft", 0) > 0


def test_shape_bytes_parse():
    assert H._bytes_of("bf16[4,8]{1,0}") == 64
    assert H._bytes_of("(f32[2,2], s32[])") == 20
    assert H._bytes_of("pred[]") == 1


def test_memory_not_dominated_by_scan_carry():
    """Stacked weights consumed via per-iteration slices must be counted
    as slice traffic, not full-array traffic per iteration."""
    L, D = 10, 64
    w = jax.ShapeDtypeStruct((L, D, D), jnp.float32)
    x = jax.ShapeDtypeStruct((4, D), jnp.float32)

    def f(w, x):
        def body(c, wl):
            return jnp.tanh(c @ wl), None

        y, _ = jax.lax.scan(body, x, w)
        return y

    hlo = _compile(f, w, x)
    a = H.analyze_hlo(hlo)
    full_per_iter = L * (L * D * D * 4)  # the overcount we must avoid
    assert a.hbm_bytes < 0.5 * full_per_iter, (a.hbm_bytes, full_per_iter)
