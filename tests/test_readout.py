"""Fused detection readout: the tiled top-K kernel vs its sort oracle,
associative-merge properties (re-chunking / permutation invariance), the
engine's fused streaming paths vs the stitched-volume oracle (paper
geometry, chunked + dedup + bf16 rows), and the serving entry points'
bitwise-identical scores across search / search_batch / pooled /
sequential / fused / stitched — plus the NaN-quarantine interaction with
``guard_scores`` on the fused path."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from _hypo import given, settings, st  # hypothesis, or deterministic fallback

from repro.core import fidelity as fid
from repro.core.engine import QueryEngine, TopKDetections, TOPK_EMPTY_IDX
from repro.core.sthc import STHCConfig
from repro.kernels.stmul import kernel as stmul_kernel
from repro.kernels.stmul import ops as stmul_ops
from repro.kernels.stmul import ref as stmul_ref
from repro.launch.resilience import TenantQuarantined
from repro.launch.serve import VideoSearchConfig, VideoSearchServer


def _kernels(rng, O=2, C=1, kh=3, kw=4, kt=3):
    return jnp.asarray(rng.randn(O, C, kh, kw, kt).astype(np.float32))


def _scores(rng, B=2, O=3, L=700, ties=True):
    v = rng.randn(B, O, L).astype(np.float32)
    if ties:
        # force exact duplicates so the index tie-break is exercised
        v[..., 1::7] = v[..., 0::7][..., : v[..., 1::7].shape[-1]]
    return jnp.asarray(v)


# -- kernel vs oracle ---------------------------------------------------------


@pytest.mark.parametrize("k", [1, 4])
def test_topk_readout_matches_sort_oracle(k, rng):
    """Pallas (multi-tile), dense jnp, and the lexsort ref all agree
    bitwise — including under deliberate score ties, where the earliest
    global position must win."""
    vals = _scores(rng)
    gidx = jnp.arange(vals.shape[-1], dtype=jnp.int32)
    s_ref, i_ref = stmul_ref.topk_readout_ref(vals, gidx, k)
    for use_pallas in (False, True):
        s, ix = stmul_ops.topk_readout(
            vals, gidx, k, use_pallas=use_pallas,
            # small tiles force the multi-tile hierarchical merge
            block_o=2 if use_pallas else None,
            block_l=128 if use_pallas else None,
        )
        assert np.array_equal(np.asarray(s), np.asarray(s_ref))
        assert np.array_equal(np.asarray(ix), np.asarray(i_ref))


def test_topk_k1_is_first_occurrence_argmax(rng):
    """k = 1 reproduces jnp.argmax's first-occurrence rule exactly."""
    vals = _scores(rng, L=300)
    gidx = jnp.arange(vals.shape[-1], dtype=jnp.int32)
    s, ix = stmul_ops.topk_readout(vals, gidx, 1, use_pallas=False)
    assert np.array_equal(
        np.asarray(ix[..., 0]), np.asarray(jnp.argmax(vals, axis=-1))
    )
    assert np.array_equal(
        np.asarray(s[..., 0]), np.asarray(jnp.max(vals, axis=-1))
    )


def test_topk_readout_tile_knob_is_bitwise_neutral(rng):
    """Every readout tile configuration selects identically — the knob
    trades launch shape, never results (the kernels_bench sweep's
    precondition)."""
    vals = _scores(rng, L=1100)
    gidx = jnp.arange(vals.shape[-1], dtype=jnp.int32)
    base = stmul_ops.topk_readout(vals, gidx, 3, use_pallas=False)
    for bo, bl in [(1, 128), (2, 256), (8, 512), (4, 2048)]:
        s, ix = stmul_ops.topk_readout(
            vals, gidx, 3, use_pallas=True, block_o=bo, block_l=bl
        )
        assert np.array_equal(np.asarray(s), np.asarray(base[0])), (bo, bl)
        assert np.array_equal(np.asarray(ix), np.asarray(base[1])), (bo, bl)


# -- associative merge properties --------------------------------------------


@settings(max_examples=12, deadline=None)
@given(
    seed=st.integers(min_value=0, max_value=2**16),
    k=st.sampled_from([1, 2, 5]),
    n_cuts=st.integers(min_value=1, max_value=6),
    perm_seed=st.integers(min_value=0, max_value=2**16),
)
def test_topk_merge_rechunk_and_permutation_invariant(
    seed, k, n_cuts, perm_seed
):
    """The property the streaming engine relies on: splitting the score
    axis at arbitrary points, reducing each segment independently, and
    merging the per-segment states *in any order* is bitwise the one-shot
    top-k.  (Total selection order ⇒ hierarchical selection is exact.)"""
    r = np.random.RandomState(seed)
    L = 257
    vals = jnp.asarray(r.randn(2, 2, L).astype(np.float32))
    gidx = jnp.arange(L, dtype=jnp.int32)
    one_shot = stmul_ops.topk_readout(vals, gidx, k, use_pallas=False)

    cuts = sorted(set(r.randint(1, L, size=n_cuts).tolist()))
    bounds = [0] + cuts + [L]
    states = [
        stmul_ops.topk_readout(
            vals[..., a:b], gidx[a:b], k, use_pallas=False
        )
        for a, b in zip(bounds[:-1], bounds[1:])
    ]
    order = np.random.RandomState(perm_seed).permutation(len(states))
    merged = stmul_ops.merge_topk([states[i] for i in order], k)
    assert np.array_equal(np.asarray(merged[0]), np.asarray(one_shot[0]))
    assert np.array_equal(np.asarray(merged[1]), np.asarray(one_shot[1]))


def test_topk_k_geq_candidates_pads_with_sentinel():
    """K larger than the candidate pool (K ≥ ties included): exhausted
    slots carry −inf / the empty sentinel, and merging exhausted states
    stays exact instead of resurrecting knocked-out candidates."""
    vals = jnp.asarray([[[3.0, 3.0, 1.0]]], jnp.float32)  # tie at the top
    gidx = jnp.arange(3, dtype=jnp.int32)
    s, ix = stmul_ops.topk_readout(vals, gidx, 5, use_pallas=False)
    assert np.array_equal(
        np.asarray(s[0, 0]), [3.0, 3.0, 1.0, -np.inf, -np.inf]
    )
    assert np.array_equal(
        np.asarray(ix[0, 0]), [0, 1, 2, TOPK_EMPTY_IDX, TOPK_EMPTY_IDX]
    )
    # hierarchical: merging two exhausted single-element states == dense
    a = stmul_ops.topk_readout(vals[..., :1], gidx[:1], 5, use_pallas=False)
    b = stmul_ops.topk_readout(vals[..., 1:], gidx[1:], 5, use_pallas=False)
    ms, mi = stmul_ops.merge_topk([a, b], 5)
    assert np.array_equal(np.asarray(ms), np.asarray(s))
    assert np.array_equal(np.asarray(mi), np.asarray(ix))


def test_topk_nan_poisons_row_and_only_that_row():
    """A NaN score saturates every slot of its (row, kernel) — scores
    NaN, indices sentinel — identically on the dense, tiled-Pallas, and
    merged paths, while other rows reduce untouched.  This is what the
    serving guard's quarantine keys on."""
    vals = np.random.RandomState(0).randn(1, 2, 400).astype(np.float32)
    vals[0, 1, 37] = np.nan
    vals = jnp.asarray(vals)
    gidx = jnp.arange(400, dtype=jnp.int32)
    clean = stmul_ops.topk_readout(vals[:, :1], gidx, 3, use_pallas=False)
    for use_pallas in (False, True):
        s, ix = stmul_ops.topk_readout(
            vals, gidx, 3, use_pallas=use_pallas,
            block_l=128 if use_pallas else None,
        )
        assert np.isnan(np.asarray(s[0, 1])).all()
        assert np.array_equal(np.asarray(ix[0, 1]), [TOPK_EMPTY_IDX] * 3)
        assert np.array_equal(np.asarray(s[0, 0]), np.asarray(clean[0][0, 0]))
        assert np.array_equal(np.asarray(ix[0, 0]), np.asarray(clean[1][0, 0]))


# -- engine: fused streaming vs the stitched-volume oracle --------------------


def _stitched_topk(vol, k):
    """Oracle: flatten the stitched (B, O, H', W', T') volume in the
    fused path's C-order and lexsort-select."""
    B, O, Hp, Wp, Tv = vol.shape
    flat = vol.reshape(B, O, -1).astype(jnp.float32)
    gidx = jnp.arange(Hp * Wp * Tv, dtype=jnp.int32)
    return stmul_ref.topk_readout_ref(flat, gidx, k)


def test_query_stream_fused_matches_stitched_paper_geometry(rng):
    """Acceptance: at the paper geometry (30×40×8 kernels, 60×80
    frames, physical fidelity), the fused streaming top-K — one-shot
    AND cursor-chunked — equals the stitched volume's reduction
    bitwise, and positions decode to the volume's argmax coordinates."""
    eng = QueryEngine(STHCConfig(fidelity=fid.physical(), osave_chunk_windows=2))
    g = eng.record(_kernels(rng, O=2, kh=30, kw=40, kt=8), (60, 80, 16))
    x = jnp.asarray(rng.rand(1, 1, 60, 80, 70).astype(np.float32))
    vol = eng.query_stream(g, x)
    det = eng.query_stream(g, x, readout_k=4)
    s_ref, i_ref = _stitched_topk(vol, 4)
    assert np.array_equal(np.asarray(det.scores), np.asarray(s_ref))
    assert np.array_equal(np.asarray(det.index), np.asarray(i_ref))
    # chunked (constant-memory cursor) == one-shot, bitwise
    det_c = eng.query_stream(g, x, readout_k=4, max_buffer_windows=2)
    assert np.array_equal(np.asarray(det_c.scores), np.asarray(det.scores))
    assert np.array_equal(np.asarray(det_c.index), np.asarray(det.index))
    # decoded positions point at the volume's peak
    t, h, w = det.positions()
    b, o = 0, 1
    assert np.asarray(vol)[
        b, o, int(h[b, o, 0]), int(w[b, o, 0]), int(t[b, o, 0])
    ] == np.asarray(det.peak_scores())[b, o]


def test_query_stream_many_fused_dedup_bf16_matches_stitched(rng):
    """Acceptance: the pooled fused path — clip-dedup union-slice rows,
    bf16 grating storage, bounded-memory chunking — is bitwise the
    stitched pooled volumes' reduction, per request."""
    eng = QueryEngine(
        STHCConfig(
            fidelity=fid.physical(),
            osave_chunk_windows=2,
            grating_dtype="bfloat16",
            keep_stacked=False,
        )
    )
    g1 = eng.record(_kernels(rng, O=2), (20, 24, 11))
    g2 = eng.record(_kernels(rng, O=3), (20, 24, 11))
    x = jnp.asarray(rng.rand(1, 1, 20, 24, 53).astype(np.float32))
    reqs = [(g1, x), (g2, x)]
    keys = [("clip",), ("clip",)]  # same content: dedup onto one row
    vols = eng.query_stream_many(reqs, clip_keys=keys)
    dets = eng.query_stream_many(reqs, clip_keys=keys, readout_k=3)
    dets_c = eng.query_stream_many(
        reqs, clip_keys=keys, readout_k=3, max_buffer_windows=2
    )
    for det, det_c, vol in zip(dets, dets_c, vols):
        assert isinstance(det, TopKDetections)
        s_ref, i_ref = _stitched_topk(vol, 3)
        assert np.array_equal(np.asarray(det.scores), np.asarray(s_ref))
        assert np.array_equal(np.asarray(det.index), np.asarray(i_ref))
        assert np.array_equal(np.asarray(det_c.scores), np.asarray(s_ref))
        assert np.array_equal(np.asarray(det_c.index), np.asarray(i_ref))


def test_fused_streaming_matches_own_stitched_volume_per_backend(rng):
    """Each backend's fused readout is bitwise its *own* stitched
    volume's reduction.  (use_pallas swaps the MAC kernel too, so the
    volumes — and hence the detections — legitimately differ across
    backends in last-bit rounding; the fused-vs-stitched equality is
    the per-backend invariant.)"""
    ker = _kernels(rng)
    x = jnp.asarray(rng.rand(2, 1, 12, 12, 40).astype(np.float32))
    for use_pallas in (False, True):
        eng = QueryEngine(
            STHCConfig(use_pallas=use_pallas, osave_chunk_windows=2)
        )
        g = eng.record(ker, (12, 12, 8))
        det = eng.query_stream(g, x, readout_k=2)
        s_ref, i_ref = _stitched_topk(eng.query_stream(g, x), 2)
        assert np.array_equal(np.asarray(det.scores), np.asarray(s_ref))
        assert np.array_equal(np.asarray(det.index), np.asarray(i_ref))


# -- serving: one readout across every entry point ---------------------------


def _server(kernels, **cfg_kw):
    cfg = VideoSearchConfig(window_frames=8, chunk_windows=2, **cfg_kw)
    srv = VideoSearchServer(frame_hw=(12, 12), cfg=cfg)
    for name, ker in kernels.items():
        srv.add_tenant(name, ker)
    return srv


@pytest.fixture
def tenant_kernels(rng):
    return {n: _kernels(rng) for n in ("a", "b")}


@pytest.fixture
def clips(rng):
    return [
        jnp.asarray(rng.rand(1, 1, 12, 12, 40).astype(np.float32))
        for _ in range(2)
    ]


def test_serve_readout_identical_across_entry_points(tenant_kernels, clips):
    """Regression for the readout-path divergence: search,
    search_batch(pooled), search_batch(sequential), fused and stitched
    all report bitwise-identical scores and peak frames."""
    fused = _server(tenant_kernels)
    stitched = _server(tenant_kernels, fused_readout=False)
    reqs = [("a", clips[0]), ("b", clips[0]), ("a", clips[1])]
    ref = fused.search_batch(reqs)
    variants = [
        fused.search_batch(reqs, pooled=False),
        stitched.search_batch(reqs),
        stitched.search_batch(reqs, pooled=False),
    ]
    for outs in variants:
        for o, r in zip(outs, ref):
            assert np.array_equal(o["scores"], r["scores"])
            assert np.array_equal(o["peak_frame"], r["peak_frame"])
    # the single-request entry point is exactly a one-request batch
    one = fused.search(clips[0], "a")
    assert np.array_equal(one["scores"], ref[0]["scores"])
    assert np.array_equal(one["peak_frame"], ref[0]["peak_frame"])
    one_s = stitched.search(clips[0], "a")
    assert np.array_equal(one_s["scores"], ref[0]["scores"])
    assert np.array_equal(one_s["peak_frame"], ref[0]["peak_frame"])


def test_serve_return_volume_forces_stitched_and_agrees(tenant_kernels, clips):
    """return_volume=True serves the stitched oracle path: the volume's
    own max/argmax reproduce the fused scores bitwise."""
    srv = _server(tenant_kernels)
    fused_out = srv.search(clips[0], "a")
    out = srv.search(clips[0], "a", return_volume=True)
    vol = np.asarray(out["volume"])
    assert vol.ndim == 5
    flat = vol.reshape(vol.shape[0], vol.shape[1], -1)
    assert np.array_equal(flat.max(-1), out["scores"])
    assert np.array_equal(out["scores"], fused_out["scores"])
    assert np.array_equal(out["peak_frame"], fused_out["peak_frame"])


def test_serve_topk_results(tenant_kernels, clips):
    """readout_topk > 1 adds per-slot scores/frames; slot 0 is the
    peak, slots descend, and they match the volume's k best."""
    srv = _server(tenant_kernels, readout_topk=3)
    out = srv.search(clips[0], "a")
    assert out["topk_scores"].shape[-1] == 3
    assert np.array_equal(out["topk_scores"][..., 0], out["scores"])
    assert np.array_equal(out["topk_frames"][..., 0], out["peak_frame"])
    assert (np.diff(out["topk_scores"], axis=-1) <= 0).all()
    vol = np.asarray(
        srv.search(clips[0], "a", return_volume=True)["volume"]
    )
    best = -np.sort(-vol.reshape(vol.shape[0], vol.shape[1], -1), -1)[..., :3]
    assert np.array_equal(best, out["topk_scores"])


def test_serve_fused_nan_quarantine_isolates_row(tenant_kernels, clips):
    """guard_scores on the fused path: a NaN anywhere in one request's
    (never-materialized) volume propagates into its peak slot and
    quarantines exactly that request; its pooled peers deliver bitwise
    the clean-run results."""
    srv = _server(tenant_kernels)
    clean = srv.search_batch([("a", clips[0]), ("b", clips[1])])
    bad = np.array(clips[0])
    bad[0, 0, 5, 5, 20] = np.nan
    outs = srv.search_batch([("a", jnp.asarray(bad)), ("b", clips[1])])
    assert isinstance(outs[0], TenantQuarantined)
    assert outs[0].tenant == "a"
    assert isinstance(outs[1], dict)
    assert np.array_equal(outs[1]["scores"], clean[1]["scores"])
    assert np.array_equal(outs[1]["peak_frame"], clean[1]["peak_frame"])
    assert srv.metrics()["quarantined"] == 1


def test_serve_guard_off_delivers_nan_scores(tenant_kernels, clips):
    """guard_scores=False: the fused path reports the NaN row as-is
    (slot saturation, sentinel positions) instead of quarantining."""
    srv = _server(tenant_kernels, guard_scores=False)
    bad = np.array(clips[0])
    bad[0, 0, 5, 5, 20] = np.nan
    out = srv.search(jnp.asarray(bad), "a")
    assert np.isnan(out["scores"]).all()
