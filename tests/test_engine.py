"""Fused spectral query engine: single-FFT dataflow, fused-vs-unfused
equivalence at paper geometry, grating cache semantics, stmul v2 vs the
v1 kernel / jnp oracle, and batched overlap-save equivalence."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import spectral_conv as sc
from repro.core import fidelity as fid
from repro.core.engine import GratingCache, QueryEngine
from repro.core.sthc import STHC, STHCConfig
from repro.kernels.stmul import ops as stmul_ops, ref as stmul_ref


def _clips(rng, B=2, C=1, H=20, W=24, T=10):
    return jnp.asarray(rng.rand(B, C, H, W, T).astype(np.float32))


def _kernels(rng, O=3, C=1, kh=7, kw=9, kt=4):
    return jnp.asarray(rng.randn(O, C, kh, kw, kt).astype(np.float32))


# -- fused query ≡ unfused two-query reference --------------------------------


def test_fused_equals_unfused_reference(rng):
    x = _clips(rng)
    k = _kernels(rng)
    sthc = STHC(STHCConfig(fidelity=fid.physical()))
    grating = sthc.record(k, x.shape[-3:])
    y_fused = sthc.engine.query(grating, x)
    y_ref = sthc.engine.query_unfused(grating, x)
    rel = float(jnp.linalg.norm(y_fused - y_ref) / jnp.linalg.norm(y_ref))
    assert rel <= 1e-4, rel


def test_fused_equals_unfused_paper_geometry(rng):
    """Acceptance geometry: the paper's 30×40×8 kernels on 60×80×16 clips."""
    x = _clips(rng, B=1, H=60, W=80, T=16)
    k = _kernels(rng, O=9, kh=30, kw=40, kt=8)
    sthc = STHC(STHCConfig(fidelity=fid.physical()))
    grating = sthc.record(k, x.shape[-3:])
    y_fused = sthc.engine.query(grating, x)
    y_ref = sthc.engine.query_unfused(grating, x)
    rel = float(jnp.linalg.norm(y_fused - y_ref) / jnp.linalg.norm(y_ref))
    assert rel <= 1e-4, rel


def test_fused_pallas_path_matches(rng):
    x = _clips(rng)
    k = _kernels(rng)
    ref = STHC(STHCConfig(fidelity=fid.physical()))(k, x)
    got = STHC(STHCConfig(fidelity=fid.physical(), use_pallas=True))(k, x)
    rel = float(jnp.linalg.norm(got - ref) / jnp.linalg.norm(ref))
    assert rel <= 1e-4, rel


def test_ideal_fused_is_exact(rng):
    x = _clips(rng)
    k = _kernels(rng)
    y = STHC(STHCConfig(fidelity=fid.ideal()))(k, x)
    ref = sc.direct_correlate3d(x, k, "valid")
    np.testing.assert_allclose(y, ref, atol=1e-4 * float(jnp.max(jnp.abs(ref))))


# -- the dataflow claim itself: exactly one forward FFT per clip --------------


def _count_ffts(jaxpr, kind: str) -> int:
    n = 0
    for eqn in jaxpr.eqns:
        if eqn.primitive.name == "fft" and eqn.params["fft_type"].name == kind:
            n += 1
        for v in eqn.params.values():
            if hasattr(v, "jaxpr"):  # ClosedJaxpr (e.g. pjit)
                n += _count_ffts(v.jaxpr, kind)
            elif hasattr(v, "eqns"):  # raw Jaxpr
                n += _count_ffts(v, kind)
    return n


def test_fused_physical_query_computes_one_forward_fft(rng):
    x = _clips(rng)
    k = _kernels(rng)
    sthc = STHC(STHCConfig(fidelity=fid.physical()))
    grating = sthc.record(k, x.shape[-3:])
    fused = jax.make_jaxpr(lambda x: sthc.engine.query(grating, x))(x)
    assert _count_ffts(fused.jaxpr, "RFFT") == 1
    assert _count_ffts(fused.jaxpr, "IRFFT") == 1
    unfused = jax.make_jaxpr(lambda x: sthc.engine.query_unfused(grating, x))(x)
    assert _count_ffts(unfused.jaxpr, "RFFT") == 2  # the cost being removed
    assert _count_ffts(unfused.jaxpr, "IRFFT") == 2


# -- grating cache -------------------------------------------------------------


def test_cache_hits_on_identical_kernels(rng):
    cache = GratingCache()
    x = _clips(rng)
    k = _kernels(rng)
    sthc = STHC(STHCConfig(fidelity=fid.physical()), cache=cache)
    y1 = sthc(k, x)
    y2 = sthc(k, x)
    assert cache.misses == 1 and cache.hits == 1
    np.testing.assert_array_equal(np.asarray(y1), np.asarray(y2))
    # same bytes in a fresh array still hits (content addressing) ...
    sthc(jnp.array(np.asarray(k)), x)
    assert cache.hits == 2
    # ... different kernel content misses
    sthc(k + 1.0, x)
    assert cache.misses == 2


def test_cache_key_separates_configs(rng):
    cache = GratingCache()
    x = _clips(rng)
    k = _kernels(rng)
    y_phys = STHC(STHCConfig(fidelity=fid.physical()), cache=cache)(k, x)
    y_ideal = STHC(STHCConfig(fidelity=fid.ideal()), cache=cache)(k, x)
    assert cache.misses == 2 and cache.hits == 0
    assert float(jnp.max(jnp.abs(y_phys - y_ideal))) > 0


def test_cache_ignores_query_only_knobs(rng):
    """Query-side config (chunking, kernel routing) doesn't change what
    was recorded — physically identical gratings must share one entry."""
    cache = GratingCache()
    x = _clips(rng)
    k = _kernels(rng)
    STHC(STHCConfig(fidelity=fid.physical()), cache=cache)(k, x)
    STHC(
        STHCConfig(fidelity=fid.physical(), use_pallas=True, osave_chunk_windows=4),
        cache=cache,
    )(k, x)
    assert cache.misses == 1 and cache.hits == 1


def test_ideal_grating_holds_single_tensor(rng):
    """Ideal mode has no ± stack; long-lived serving gratings must not
    retain redundant copies (stacked is None, plus aliases effective)."""
    k = _kernels(rng)
    g = QueryEngine(STHCConfig(fidelity=fid.ideal())).record(k, (20, 24, 10))
    assert g.stacked is None and g.minus is None
    assert g.plus is g.effective


def test_cache_bypassed_under_tracing(rng):
    cache = GratingCache()
    x = _clips(rng)
    k = _kernels(rng)
    sthc = STHC(STHCConfig(fidelity=fid.physical()), cache=cache)

    @jax.jit
    def run(k, x):
        return sthc(k, x)

    y = run(k, x)
    assert cache.misses == 0 and cache.hits == 0 and len(cache) == 0
    ref = STHC(STHCConfig(fidelity=fid.physical()))(k, x)
    np.testing.assert_allclose(y, ref, rtol=0, atol=1e-5 * float(jnp.max(jnp.abs(ref))))


def test_cache_lru_eviction(rng):
    cache = GratingCache(max_entries=2)
    x = _clips(rng)
    sthc = STHC(STHCConfig(fidelity=fid.ideal()), cache=cache)
    ks = [_kernels(np.random.RandomState(i)) for i in range(3)]
    for k in ks:
        sthc(k, x)
    assert len(cache) == 2 and cache.misses == 3
    sthc(ks[0], x)  # evicted → miss again
    assert cache.misses == 4


def test_cache_inflight_dedup_concurrent_misses(rng):
    """Concurrent misses for one key run engine.record exactly once —
    the losers wait on the in-flight recorder instead of thundering."""
    import threading
    import time as _time

    cache = GratingCache(max_entries=4)
    eng = QueryEngine(STHCConfig(fidelity=fid.ideal()))
    k = _kernels(rng)
    calls = []
    orig = eng.record

    def slow_record(kernels, signal_shape):
        calls.append(1)
        _time.sleep(0.05)  # widen the race window
        return orig(kernels, signal_shape)

    eng.record = slow_record
    results = []

    def worker():
        results.append(cache.get_or_record(eng, k, (20, 24, 10)))

    threads = [threading.Thread(target=worker) for _ in range(4)]
    for t in threads:
        t.start()
    for t in threads:
        t.join()
    assert len(calls) == 1
    assert cache.misses == 1 and cache.hits == 3
    assert all(r is results[0] for r in results)


# -- stmul v2 ≡ v1 ≡ oracle -----------------------------------------------------


@pytest.mark.parametrize("C", [1, 3, 8, 9])  # spans the VPU/MXU routing split
def test_stmul_v2_matches_v1_and_oracle(C):
    rng = np.random.RandomState(C)
    sh = (6, 7, 5)
    xh = jnp.asarray(
        (rng.randn(2, C, *sh) + 1j * rng.randn(2, C, *sh)).astype(np.complex64)
    )
    g = jnp.asarray(
        (rng.randn(4, C, *sh) + 1j * rng.randn(4, C, *sh)).astype(np.complex64)
    )
    ref = stmul_ref.spectral_mac_ref(xh, g)
    tol = 1e-4 * float(jnp.max(jnp.abs(ref))) + 1e-6
    v1 = stmul_ops.spectral_mac(xh, g, version=1)
    v2 = stmul_ops.spectral_mac(xh, g, version=2)
    np.testing.assert_allclose(v1, ref, atol=tol)
    np.testing.assert_allclose(v2, ref, atol=tol)
    np.testing.assert_allclose(v2, v1, atol=tol)


def test_stmul_v2_tile_boundary():
    """F at / off the 512-lane tile boundary through the v2 kernel."""
    rng = np.random.RandomState(0)
    for F in (511, 512, 513):
        xh = jnp.asarray(
            (rng.randn(2, 1, F) + 1j * rng.randn(2, 1, F)).astype(np.complex64)
        )
        g = jnp.asarray(
            (rng.randn(3, 1, F) + 1j * rng.randn(3, 1, F)).astype(np.complex64)
        )
        got = stmul_ops.spectral_mac(xh, g, version=2)
        ref = stmul_ref.spectral_mac_ref(xh, g)
        np.testing.assert_allclose(got, ref, atol=1e-4)


def test_stmul_unknown_version_raises():
    xh = jnp.zeros((1, 1, 4, 4, 3), jnp.complex64)
    g = jnp.zeros((1, 1, 4, 4, 3), jnp.complex64)
    with pytest.raises(ValueError):
        stmul_ops.spectral_mac(xh, g, version=3)


# -- streaming (engine-owned overlap-save) ------------------------------------


@pytest.mark.parametrize("T", [9, 23, 37])  # ragged vs window/chunk grids
@pytest.mark.parametrize("chunk", [1, 2, 3, 8])
def test_batched_overlap_save_equals_one_shot(T, chunk, rng):
    x = jnp.asarray(rng.rand(1, 1, 10, 12, T).astype(np.float32))
    k = jnp.asarray(rng.randn(2, 1, 3, 4, 3).astype(np.float32))
    ref = sc.direct_correlate3d(x, k, mode="valid")
    sthc = STHC(STHCConfig(fidelity=fid.ideal(), osave_chunk_windows=chunk))
    got = sthc.correlate_stream(k, x, block_t=7)
    np.testing.assert_allclose(
        got, ref, atol=2e-4 * float(jnp.max(jnp.abs(ref))) + 1e-5
    )


def test_correlate_stream_uses_cache_and_chunks(rng):
    cache = GratingCache()
    x = jnp.asarray(rng.rand(1, 1, 10, 12, 29).astype(np.float32))
    k = jnp.asarray(rng.randn(2, 1, 3, 4, 3).astype(np.float32))
    sthc = STHC(STHCConfig(fidelity=fid.ideal(), osave_chunk_windows=3), cache=cache)
    ref = sc.direct_correlate3d(x, k, mode="valid")
    got = sthc.correlate_stream(k, x, block_t=8)
    np.testing.assert_allclose(
        got, ref, atol=2e-4 * float(jnp.max(jnp.abs(ref))) + 1e-5
    )
    sthc.correlate_stream(k, x, block_t=8)
    assert cache.hits == 1 and cache.misses == 1


@pytest.mark.parametrize("T", [33, 40])  # ragged vs window/chunk grids
@pytest.mark.parametrize("chunk", [1, 4])
def test_streaming_physical_equals_one_shot_paper_geometry(T, chunk, rng):
    """The pinned acceptance property: streaming physical correlation ==
    one-shot physical correlation at the paper geometry (30×40×8 kernels
    on 60×80 frames).  Record-time physics live on the reference's own
    temporal grid and query encoding uses a stream-global SLM scale, so
    the coherence-window decomposition is exactly lossless — the
    deployment of Fig. 1C serves the *same* physical model the accuracy
    experiments validate."""
    x = jnp.asarray(rng.rand(1, 1, 60, 80, T).astype(np.float32))
    k = jnp.asarray(rng.randn(9, 1, 30, 40, 8).astype(np.float32))
    ref = STHC(STHCConfig(fidelity=fid.physical()))(k, x)
    sthc = STHC(STHCConfig(fidelity=fid.physical(), osave_chunk_windows=chunk))
    got = sthc.correlate_stream(k, x, block_t=16)
    rel = float(jnp.linalg.norm(got - ref) / jnp.linalg.norm(ref))
    assert rel <= 1e-4, rel


def test_streaming_physical_small_geometry_ragged(rng):
    """Same property off the paper grid: ragged T vs block, odd shapes."""
    x = jnp.asarray(rng.rand(2, 1, 20, 24, 29).astype(np.float32))
    k = jnp.asarray(rng.randn(3, 1, 7, 9, 4).astype(np.float32))
    ref = STHC(STHCConfig(fidelity=fid.physical()))(k, x)
    got = STHC(
        STHCConfig(fidelity=fid.physical(), osave_chunk_windows=3)
    ).correlate_stream(k, x, block_t=11)
    rel = float(jnp.linalg.norm(got - ref) / jnp.linalg.norm(ref))
    assert rel <= 1e-4, rel


def test_query_stream_rejects_mismatched_frame_size(rng):
    k = jnp.asarray(rng.randn(2, 1, 3, 4, 3).astype(np.float32))
    sthc = STHC(STHCConfig(fidelity=fid.ideal()))
    grating = sthc.record(k, (12, 12, 8))
    with pytest.raises(ValueError, match="spatial dims"):
        sthc.engine.query_stream(grating, jnp.zeros((1, 1, 16, 16, 20)))


def test_video_server_rejects_mismatched_frame_size(rng):
    from repro.launch.serve import VideoSearchConfig, VideoSearchServer

    k = jnp.asarray(rng.randn(2, 1, 3, 4, 3).astype(np.float32))
    server = VideoSearchServer(k, (12, 12), VideoSearchConfig(window_frames=8))
    # the server pre-validates geometry upfront (before any device work)
    with pytest.raises(ValueError, match="server frame size"):
        server.search(jnp.zeros((1, 1, 16, 16, 20), jnp.float32))


def test_video_server_serves_physical_mode(rng):
    """The old NotImplementedError path is gone: physical-mode serving
    scores equal the one-shot physical correlator's peak responses."""
    from repro.launch.serve import VideoSearchConfig, VideoSearchServer

    k = jnp.asarray(rng.randn(2, 1, 3, 4, 3).astype(np.float32))
    clip = jnp.asarray(rng.rand(1, 1, 12, 12, 20).astype(np.float32))
    server = VideoSearchServer(
        k, (12, 12), VideoSearchConfig(window_frames=8, fidelity=fid.physical())
    )
    out = server.search(clip)
    ref = STHC(STHCConfig(fidelity=fid.physical()))(k, clip)
    want = np.asarray(jnp.max(ref.reshape(1, 2, -1), axis=-1))
    np.testing.assert_allclose(out["scores"], want, rtol=1e-4)


# -- stmul MXU-routing knob ---------------------------------------------------


@pytest.mark.parametrize("min_mxu_c", [1, 99])  # force MXU / force VPU
@pytest.mark.parametrize("C", [3, 8])
def test_stmul_min_mxu_c_routing_matches_oracle(min_mxu_c, C):
    """Both contraction routes agree with the oracle at any threshold —
    the real-TPU tuning knob changes routing, never semantics."""
    rng = np.random.RandomState(C)
    sh = (6, 7, 5)
    xh = jnp.asarray(
        (rng.randn(2, C, *sh) + 1j * rng.randn(2, C, *sh)).astype(np.complex64)
    )
    g = jnp.asarray(
        (rng.randn(4, C, *sh) + 1j * rng.randn(4, C, *sh)).astype(np.complex64)
    )
    ref = stmul_ref.spectral_mac_ref(xh, g)
    got = stmul_ops.spectral_mac(xh, g, version=2, min_mxu_c=min_mxu_c)
    np.testing.assert_allclose(
        got, ref, atol=1e-4 * float(jnp.max(jnp.abs(ref))) + 1e-6
    )


def test_stmul_min_mxu_c_routed_from_config(rng):
    """STHCConfig.stmul_min_mxu_c reaches the kernel: forcing the MXU
    route through the engine still matches the jnp path."""
    x = _clips(rng, C=3)
    k = _kernels(rng, C=3)
    ref = STHC(STHCConfig(fidelity=fid.physical()))(k, x)
    got = STHC(
        STHCConfig(fidelity=fid.physical(), use_pallas=True, stmul_min_mxu_c=1)
    )(k, x)
    rel = float(jnp.linalg.norm(got - ref) / jnp.linalg.norm(ref))
    assert rel <= 1e-4, rel


# -- engine as a pure function ----------------------------------------------------


def test_engine_record_query_jit_friendly(rng):
    """record + query compose under jit (grating as closed-over constant)."""
    x = _clips(rng)
    k = _kernels(rng)
    engine = QueryEngine(STHCConfig(fidelity=fid.physical()))
    grating = engine.record(k, x.shape[-3:])
    eager = engine.query(grating, x)
    jitted = jax.jit(lambda x: engine.query(grating, x))(x)
    np.testing.assert_allclose(
        eager, jitted, atol=1e-5 * float(jnp.max(jnp.abs(eager))) + 1e-6
    )


# -- pooled cross-tenant executor ---------------------------------------------


def test_query_many_matches_query_loop(rng):
    """Pooled one-shot answers equal the per-tenant query loop: mixed O,
    a duplicate grating (two requests, one tenant) and mixed batch
    sizes in one call."""
    x1, x2 = _clips(rng, B=2), _clips(rng, B=1)
    eng = QueryEngine(STHCConfig(fidelity=fid.physical()))
    g1 = eng.record(_kernels(rng, O=3), (20, 24, 10))
    g2 = eng.record(_kernels(rng, O=5), (20, 24, 10))
    outs = eng.query_many([(g1, x1), (g2, x2), (g1, x2)])
    refs = [eng.query(g1, x1), eng.query(g2, x2), eng.query(g1, x2)]
    for out, ref in zip(outs, refs):
        assert out.shape == ref.shape
        rel = float(jnp.linalg.norm(out - ref) / jnp.linalg.norm(ref))
        assert rel <= 1e-5, rel


def test_query_many_paper_geometry_mixed_fidelity_one_pool_group(rng):
    """Acceptance: at the paper geometry, two tenants at *different*
    fidelities that share encode semantics and FFT geometry occupy ONE
    pool group — a single pooled dispatch serves both, equal to the
    per-tenant loop."""
    x = _clips(rng, B=1, H=60, W=80, T=16)
    k1 = _kernels(rng, O=9, kh=30, kw=40, kt=8)
    k2 = _kernels(rng, O=9, kh=30, kw=40, kt=8)
    eng_phys = QueryEngine(STHCConfig(fidelity=fid.physical()))
    sub = fid.pipeline(
        fid.PseudoNegative(), fid.SLMQuantize(), fid.IHBEnvelope(),
        name="sub",
    )
    eng_sub = QueryEngine(STHCConfig(fidelity=sub))
    g1 = eng_phys.record(k1, (60, 80, 16))
    g2 = eng_sub.record(k2, (60, 80, 16))
    requests = [(g1, x), (g2, x)]
    # same encode semantics (SLM at 8 bits) + same FFT grid -> one group
    assert len(eng_phys._group_requests(requests)) == 1
    outs = eng_phys.query_many(requests)
    refs = [eng_phys.query(g1, x), eng_sub.query(g2, x)]
    for out, ref in zip(outs, refs):
        rel = float(jnp.linalg.norm(out - ref) / jnp.linalg.norm(ref))
        assert rel <= 1e-5, rel


def test_pooled_dispatch_single_forward_fft(rng):
    """The pooled dataflow claim: one group dispatch = exactly one
    forward FFT + one inverse FFT, however many tenants it serves."""
    from repro.core.engine import _dedup_members

    x = _clips(rng, B=2)
    eng = QueryEngine(STHCConfig(fidelity=fid.physical()))
    g1 = eng.record(_kernels(rng, O=3), (20, 24, 10))
    g2 = eng.record(_kernels(rng, O=3), (20, 24, 10))
    members, slot_of = _dedup_members([g1, g2])
    pool = eng._pool_for(members)
    rows = np.asarray(
        [pool.o_start[slot_of[0]], pool.o_start[slot_of[1]]], np.int32
    )
    jaxpr = jax.make_jaxpr(
        lambda x: eng._pooled_dispatch(x, pool, rows, g1)
    )(x)
    assert _count_ffts(jaxpr.jaxpr, "RFFT") == 1
    assert _count_ffts(jaxpr.jaxpr, "IRFFT") == 1


@pytest.mark.parametrize("chunk", [1, 3])
def test_query_stream_many_matches_stream_loop(chunk, rng):
    """Pooled streaming equals per-tenant query_stream: ragged T vs the
    window grid, physical encoding, chunked windows."""
    cfg = STHCConfig(fidelity=fid.physical(), osave_chunk_windows=chunk)
    eng = QueryEngine(cfg)
    g1 = eng.record(_kernels(rng, O=2), (20, 24, 11))
    g2 = eng.record(_kernels(rng, O=4), (20, 24, 11))
    x1 = jnp.asarray(rng.rand(1, 1, 20, 24, 29).astype(np.float32))
    x2 = jnp.asarray(rng.rand(2, 1, 20, 24, 29).astype(np.float32))
    outs = eng.query_stream_many([(g1, x1), (g2, x2)])
    refs = [eng.query_stream(g1, x1), eng.query_stream(g2, x2)]
    for out, ref in zip(outs, refs):
        rel = float(jnp.linalg.norm(out - ref) / jnp.linalg.norm(ref))
        assert rel <= 1e-5, rel


def test_query_many_pallas_grouped_matches_dense(rng):
    """The grouped Pallas launch and the dense gather path agree."""
    x = _clips(rng, B=2)
    dense = QueryEngine(STHCConfig(fidelity=fid.physical()))
    pallas = QueryEngine(
        STHCConfig(fidelity=fid.physical(), use_pallas=True)
    )
    k1, k2 = _kernels(rng, O=3), _kernels(rng, O=5)
    gd1, gd2 = dense.record(k1, (20, 24, 10)), dense.record(k2, (20, 24, 10))
    gp1, gp2 = pallas.record(k1, (20, 24, 10)), pallas.record(k2, (20, 24, 10))
    outs_d = dense.query_many([(gd1, x), (gd2, x)])
    outs_p = pallas.query_many([(gp1, x), (gp2, x)])
    for d, p in zip(outs_d, outs_p):
        rel = float(jnp.linalg.norm(p - d) / jnp.linalg.norm(d))
        assert rel <= 1e-4, rel


def test_pool_arena_reused_across_calls(rng):
    """The packed arena is a stable buffer: repeated dispatches with the
    same resident gratings hit one memoized GratingPool."""
    x = _clips(rng)
    eng = QueryEngine(STHCConfig(fidelity=fid.ideal()))
    g1 = eng.record(_kernels(rng, O=2), (20, 24, 10))
    g2 = eng.record(_kernels(rng, O=2), (20, 24, 10))
    eng.query_many([(g1, x), (g2, x)])
    pools_after_first = len(eng._pools)
    eng.query_many([(g1, x), (g2, x)])
    eng.query_many([(g1, x), (g2, x)])
    assert len(eng._pools) == pools_after_first == 1


def test_query_many_rejects_channel_mismatch(rng):
    eng = QueryEngine(STHCConfig(fidelity=fid.ideal()))
    g = eng.record(_kernels(rng, C=1), (20, 24, 10))
    with pytest.raises(ValueError, match="channels"):
        eng.query_many([(g, _clips(rng, C=3))])


# -- grouped stmul kernel vs the v1 loop oracle --------------------------------


@pytest.mark.parametrize("C", [1, 8])  # spans the VPU/MXU routing split
def test_stmul_grouped_matches_loop_oracle(C):
    """One grouped launch over a pooled arena equals the per-request v1
    loop oracle — shared offsets included (two rows, one tenant)."""
    rng = np.random.RandomState(C)
    sh = (6, 7, 5)
    B, n_out, block_o = 4, 4, 4
    pool = (rng.randn(12, C, *sh) + 1j * rng.randn(12, C, *sh)).astype(
        np.complex64
    )
    xh = jnp.asarray(
        (rng.randn(B, C, *sh) + 1j * rng.randn(B, C, *sh)).astype(
            np.complex64
        )
    )
    o_start = np.array([0, 4, 8, 4], np.int32)  # row 3 shares tenant 1
    ref = stmul_ref.spectral_mac_grouped_ref(
        xh, jnp.asarray(pool), o_start, n_out
    )
    got = stmul_ops.spectral_mac_grouped(
        xh,
        jnp.asarray(pool.real),
        jnp.asarray(pool.imag),
        o_start,
        n_out,
        block_o=block_o,
    )
    tol = 1e-4 * float(jnp.max(jnp.abs(ref))) + 1e-6
    np.testing.assert_allclose(got, ref, atol=tol)
    # bf16 arena planes (half-precision grating storage): f32-accumulated
    got_bf = stmul_ops.spectral_mac_grouped(
        xh,
        jnp.asarray(pool.real, jnp.bfloat16),
        jnp.asarray(pool.imag, jnp.bfloat16),
        o_start,
        n_out,
        block_o=block_o,
    )
    rel = float(jnp.linalg.norm(got_bf - ref) / jnp.linalg.norm(ref))
    assert rel <= 2e-2, rel


# -- half-precision (bf16 split-real) grating storage --------------------------


def test_bf16_storage_halves_nbytes_and_cache_bytes(rng):
    """STHCConfig.grating_dtype='bfloat16' stores split-real planes at
    exactly half the serving grating's bytes, and the cache byte
    accounting sees the halved footprint."""
    k = _kernels(rng)
    for pipe in (fid.ideal(), fid.physical()):
        f32 = QueryEngine(
            STHCConfig(fidelity=pipe, keep_stacked=False)
        ).record(k, (20, 24, 10))
        bf16 = QueryEngine(
            STHCConfig(
                fidelity=pipe, keep_stacked=False, grating_dtype="bfloat16"
            )
        ).record(k, (20, 24, 10))
        assert bf16.storage_dtype == "bfloat16"
        assert bf16.effective is None and bf16.eff_re is not None
        assert bf16.nbytes * 2 == f32.nbytes
    cache = GratingCache()
    sthc = STHC(
        STHCConfig(
            fidelity=fid.physical(),
            keep_stacked=False,
            grating_dtype="bfloat16",
        ),
        cache=cache,
    )
    g = sthc.record(k, (20, 24, 10))
    assert cache.nbytes == g.nbytes


def test_bf16_pooled_query_close_to_f32(rng):
    """bf16-at-rest, f32-accumulation: one-shot and pooled queries stay
    within tolerance of the f32 grating, and the pooled bf16 answer
    equals the per-tenant bf16 query."""
    x = _clips(rng)
    k = _kernels(rng)
    f32 = QueryEngine(STHCConfig(fidelity=fid.physical()))
    bf16 = QueryEngine(
        STHCConfig(fidelity=fid.physical(), grating_dtype="bfloat16")
    )
    gf, gb = f32.record(k, (20, 24, 10)), bf16.record(k, (20, 24, 10))
    yf, yb = f32.query(gf, x), bf16.query(gb, x)
    rel = float(jnp.linalg.norm(yb - yf) / jnp.linalg.norm(yf))
    assert rel <= 2e-2, rel
    (pooled,) = bf16.query_many([(gb, x)])
    rel = float(jnp.linalg.norm(pooled - yb) / jnp.linalg.norm(yb))
    assert rel <= 1e-5, rel


def test_bf16_cache_key_never_aliases_f32(rng):
    """Same kernel bytes under the two storage dtypes are two cache
    entries — a lookup can never serve the other precision's grating."""
    cache = GratingCache()
    x = _clips(rng)
    k = _kernels(rng)
    STHC(STHCConfig(fidelity=fid.ideal()), cache=cache)(k, x)
    STHC(
        STHCConfig(fidelity=fid.ideal(), grating_dtype="bfloat16"),
        cache=cache,
    )(k, x)
    assert cache.misses == 2 and cache.hits == 0


def test_default_storage_layout_unchanged(rng):
    """grating_dtype defaults to f32: the recorded layout is the
    pre-knob complex64 tensor (bit-identical paths), and unknown
    dtypes are rejected loudly."""
    g = QueryEngine(STHCConfig(fidelity=fid.physical())).record(
        _kernels(rng), (20, 24, 10)
    )
    assert g.storage_dtype == "float32"
    assert g.effective is not None and g.eff_re is None
    assert g.effective.dtype == jnp.complex64
    with pytest.raises(ValueError, match="grating_dtype"):
        STHCConfig(fidelity=fid.ideal(), grating_dtype="float16")


# -- shared-stream clip-dedup + bounded-memory streaming ----------------------


def test_query_many_clip_dedup_paper_geometry_matches_loop(rng):
    """Acceptance: deduped shared-stream fan-out equals the per-request
    loop to float tolerance at the paper geometry — four tenants'
    kernel banks correlated against ONE clip in parallel (the paper's
    headline dataflow), answered from one physical batch row reading
    the union of their O-slices."""
    x = _clips(rng, B=1, H=60, W=80, T=16)
    eng = QueryEngine(STHCConfig(fidelity=fid.physical()))
    gs = [
        eng.record(_kernels(rng, O=3, kh=30, kw=40, kt=8), (60, 80, 16))
        for _ in range(4)
    ]
    before = eng.pool_stats()
    outs = eng.query_many([(g, x) for g in gs])
    after = eng.pool_stats()
    for g, out in zip(gs, outs):
        ref = eng.query(g, x)
        rel = float(jnp.linalg.norm(out - ref) / jnp.linalg.norm(ref))
        assert rel <= 1e-5, rel
    # 4 clip rows offered, 1 physical row dispatched
    assert after["rows_offered"] - before["rows_offered"] == 4
    assert after["rows_dispatched"] - before["rows_dispatched"] == 1
    assert after["rows_saved"] - before["rows_saved"] == 3


def test_query_many_dedup_is_content_addressed_not_identity(rng):
    """Two distinct array objects with equal bytes dedup; equal shapes
    with different bytes do not."""
    a = rng.rand(1, 1, 20, 24, 10).astype(np.float32)
    same = jnp.asarray(a.copy())
    also_same = jnp.asarray(a.copy())
    different = jnp.asarray(rng.rand(1, 1, 20, 24, 10).astype(np.float32))
    eng = QueryEngine(STHCConfig(fidelity=fid.ideal()))
    g1 = eng.record(_kernels(rng, O=2), (20, 24, 10))
    g2 = eng.record(_kernels(rng, O=3), (20, 24, 10))
    before = eng.pool_stats()
    outs = eng.query_many([(g1, same), (g2, also_same), (g1, different)])
    delta = {
        k: eng.pool_stats()[k] - before[k] for k in ("rows_offered", "rows_dispatched")
    }
    assert delta == {"rows_offered": 3, "rows_dispatched": 2}
    for out, (g, x) in zip(outs, [(g1, same), (g2, also_same), (g1, different)]):
        ref = eng.query(g, x)
        rel = float(jnp.linalg.norm(out - ref) / jnp.linalg.norm(ref))
        assert rel <= 1e-5, rel


def test_query_many_dedup_off_is_row_per_request(rng):
    """dedup=False keeps the one-row-per-request baseline (the
    benchmark's undeduped pooled mode) with identical answers."""
    x = _clips(rng, B=1)
    eng = QueryEngine(STHCConfig(fidelity=fid.physical()))
    g1 = eng.record(_kernels(rng, O=2), (20, 24, 10))
    g2 = eng.record(_kernels(rng, O=4), (20, 24, 10))
    before = eng.pool_stats()
    outs = eng.query_many([(g1, x), (g2, x)], dedup=False)
    after = eng.pool_stats()
    assert after["rows_dispatched"] - before["rows_dispatched"] == 2
    assert after["rows_saved"] == before["rows_saved"]
    for out, g in zip(outs, (g1, g2)):
        ref = eng.query(g, x)
        rel = float(jnp.linalg.norm(out - ref) / jnp.linalg.norm(ref))
        assert rel <= 1e-5, rel


def test_query_stream_many_clip_dedup_paper_geometry_matches_loop(rng):
    """Acceptance (streaming): N tenants fanning out over one shared
    stream — pooled + deduped overlap-save equals the per-request
    query_stream loop to float tolerance at the paper's frame/kernel
    geometry, and the whole fan-out dispatches ONE physical clip row."""
    cfg = STHCConfig(fidelity=fid.physical(), osave_chunk_windows=2)
    eng = QueryEngine(cfg)
    gs = [
        eng.record(_kernels(rng, O=3, kh=30, kw=40, kt=8), (60, 80, 16))
        for _ in range(3)
    ]
    x = jnp.asarray(rng.rand(1, 1, 60, 80, 40).astype(np.float32))
    before = eng.pool_stats()
    outs = eng.query_stream_many([(g, x) for g in gs])
    after = eng.pool_stats()
    assert after["rows_offered"] - before["rows_offered"] == 3
    assert after["rows_dispatched"] - before["rows_dispatched"] == 1
    for g, out in zip(gs, outs):
        ref = eng.query_stream(g, x)
        rel = float(jnp.linalg.norm(out - ref) / jnp.linalg.norm(ref))
        assert rel <= 1e-5, rel


def test_query_stream_many_dedup_mixed_clips_and_batches(rng):
    """Dedup with a mixed composition: two tenants on one shared stream
    plus a third on its own — splits slice the right O-windows out of
    the shared row's union span."""
    eng = QueryEngine(STHCConfig(fidelity=fid.physical()))
    g1 = eng.record(_kernels(rng, O=2), (20, 24, 11))
    g2 = eng.record(_kernels(rng, O=5), (20, 24, 11))
    shared = jnp.asarray(rng.rand(1, 1, 20, 24, 29).astype(np.float32))
    own = jnp.asarray(rng.rand(1, 1, 20, 24, 29).astype(np.float32))
    outs = eng.query_stream_many([(g1, shared), (g2, shared), (g2, own)])
    refs = [
        eng.query_stream(g1, shared),
        eng.query_stream(g2, shared),
        eng.query_stream(g2, own),
    ]
    for out, ref in zip(outs, refs):
        assert out.shape == ref.shape
        rel = float(jnp.linalg.norm(out - ref) / jnp.linalg.norm(ref))
        assert rel <= 1e-5, rel


def test_query_stream_many_dedup_pallas_matches_dense(rng):
    """The grouped Pallas launch serves dedup union spans (aligned
    row offsets + dispatch-time arena padding) identically to the
    dense gather path."""
    k1, k2 = _kernels(rng, O=2, C=2), _kernels(rng, O=3, C=2)
    dense = QueryEngine(STHCConfig(fidelity=fid.ideal()))
    pallas = QueryEngine(STHCConfig(fidelity=fid.ideal(), use_pallas=True))
    gd1, gd2 = dense.record(k1, (20, 24, 10)), dense.record(k2, (20, 24, 10))
    gp1, gp2 = pallas.record(k1, (20, 24, 10)), pallas.record(k2, (20, 24, 10))
    x = _clips(rng, B=1, C=2, T=26)
    outs_d = dense.query_stream_many([(gd1, x), (gd2, x)])
    outs_p = pallas.query_stream_many([(gp1, x), (gp2, x)])
    for d, p in zip(outs_d, outs_p):
        rel = float(jnp.linalg.norm(p - d) / jnp.linalg.norm(d))
        assert rel <= 1e-4, rel


@pytest.mark.parametrize("fidelity", ["ideal", "physical"])
def test_query_stream_chunked_cursor_equals_one_shot(fidelity, rng):
    """Acceptance: bounded-memory chunked streaming equals the one-shot
    (unbounded) correlation to float tolerance, at constant peak
    buffer, for both an un-encoded and an SLM-encoded pipeline (the
    stream-global scale must survive chunking)."""
    pipe = fid.ideal() if fidelity == "ideal" else fid.physical()
    eng = QueryEngine(STHCConfig(fidelity=pipe, osave_chunk_windows=2))
    g = eng.record(_kernels(rng, O=2, kh=7, kw=9, kt=4), (20, 24, 12))
    x = jnp.asarray(rng.rand(2, 1, 20, 24, 77).astype(np.float32))
    one_shot = eng.query_stream(g, x)
    chunked = eng.query_stream(g, x, max_buffer_windows=3)
    np.testing.assert_allclose(
        np.asarray(chunked),
        np.asarray(one_shot),
        atol=1e-6 * float(jnp.max(jnp.abs(one_shot))),
    )
    # the cursor really ran multiple bounded segments
    plan = eng.stream_plan_for(g, x.shape[-1])
    cursor = sc.StreamCursor(plan, 3)
    assert len(cursor) > 1
    assert cursor.peak_buffer_frames == 2 * plan.step + plan.block_t


def test_query_stream_chunked_paper_geometry_long_clip(rng):
    """Acceptance at paper geometry: a stream far longer than the
    device buffer (max_buffer_windows windows) serves exactly equal to
    one-shot streaming; every segment buffer stays at the constant
    bound regardless of T."""
    cfg = STHCConfig(fidelity=fid.physical())
    eng = QueryEngine(cfg)
    g = eng.record(_kernels(rng, O=2, kh=30, kw=40, kt=8), (60, 80, 16))
    x = jnp.asarray(rng.rand(1, 1, 60, 80, 70).astype(np.float32))
    one_shot = eng.query_stream(g, x)
    chunked = eng.query_stream(g, x, max_buffer_windows=2)
    np.testing.assert_allclose(
        np.asarray(chunked),
        np.asarray(one_shot),
        atol=1e-6 * float(jnp.max(jnp.abs(one_shot))),
    )
    plan = eng.stream_plan_for(g, x.shape[-1])
    cursor = sc.StreamCursor(plan, 2)
    bound = plan.step + plan.block_t
    assert all(seg.frames <= bound for seg in cursor)
    # the bound is independent of T: a 10x longer stream plans the same
    # per-segment buffer
    long_plan = eng.stream_plan_for(g, 10 * x.shape[-1])
    assert sc.StreamCursor(long_plan, 2).peak_buffer_frames <= bound


def test_query_stream_many_chunked_matches_unchunked(rng):
    """Pooled + deduped + chunked: the full stream-centric hot path
    equals the unbounded pooled pass and the per-request loop."""
    eng = QueryEngine(STHCConfig(fidelity=fid.physical()))
    g1 = eng.record(_kernels(rng, O=2), (20, 24, 11))
    g2 = eng.record(_kernels(rng, O=3), (20, 24, 11))
    x = jnp.asarray(rng.rand(1, 1, 20, 24, 53).astype(np.float32))
    unbounded = eng.query_stream_many([(g1, x), (g2, x)])
    bounded = eng.query_stream_many(
        [(g1, x), (g2, x)], max_buffer_windows=2
    )
    for u, b, g in zip(unbounded, bounded, (g1, g2)):
        np.testing.assert_allclose(
            np.asarray(b), np.asarray(u),
            atol=1e-6 * float(jnp.max(jnp.abs(u))),
        )
        ref = eng.query_stream(g, x)
        rel = float(jnp.linalg.norm(b - ref) / jnp.linalg.norm(ref))
        assert rel <= 1e-5, rel


def test_osave_max_buffer_windows_config_validation():
    with pytest.raises(ValueError, match="osave_max_buffer_windows"):
        STHCConfig(fidelity=fid.ideal(), osave_max_buffer_windows=0)
    cfg = STHCConfig(fidelity=fid.ideal(), osave_max_buffer_windows=4)
    assert cfg.osave_max_buffer_windows == 4
