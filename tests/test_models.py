"""Per-architecture smoke tests (reduced same-family configs): one
forward/train step on CPU asserting output shapes + finite values, plus
prefill/decode consistency where the architecture admits exactness."""

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import configs
from repro.models import model_api

ARCHS = configs.arch_names()


def _batch(cfg, B=2, S=24, seed=1):
    rng = jax.random.PRNGKey(seed)
    batch = {
        "tokens": jax.random.randint(rng, (B, S), 0, cfg.vocab),
        "labels": jax.random.randint(rng, (B, S), 0, cfg.vocab),
    }
    if cfg.family == "audio":
        batch["frames"] = jax.random.normal(rng, (B, cfg.n_frames, cfg.d_model))
    if cfg.family == "vlm":
        batch["patches"] = jax.random.normal(rng, (B, cfg.n_patches, cfg.d_model))
    return batch


@pytest.mark.parametrize("arch", ARCHS)
def test_smoke_forward_and_grad(arch):
    cfg = configs.get_smoke_config(arch)
    mod = model_api.get_model(cfg)
    params, axes = mod.init_params(cfg, jax.random.PRNGKey(0))
    batch = _batch(cfg)
    loss, grads = jax.value_and_grad(lambda p: mod.loss_fn(cfg, p, batch))(params)
    assert np.isfinite(float(loss))
    # loss near ln(vocab) at init (uniform predictions)
    assert abs(float(loss) - np.log(cfg.vocab)) < 2.5
    for leaf in jax.tree.leaves(grads):
        assert bool(jnp.all(jnp.isfinite(leaf)))
    # axes tree parallels params tree
    assert len(jax.tree.leaves(params)) == len(
        jax.tree.leaves(
            axes,
            is_leaf=lambda x: isinstance(x, tuple)
            and all(isinstance(e, (str, type(None))) for e in x),
        )
    )


@pytest.mark.parametrize("arch", ARCHS)
def test_smoke_decode_runs(arch):
    cfg = configs.get_smoke_config(arch)
    mod = model_api.get_model(cfg)
    params, _ = mod.init_params(cfg, jax.random.PRNGKey(0))
    batch = _batch(cfg, S=8)
    if cfg.family in ("audio", "vlm"):
        prompt = {k: v for k, v in batch.items() if k != "labels"}
    else:
        prompt = batch["tokens"]
    logits, cache = mod.prefill(cfg, params, prompt, max_len=24)
    assert logits.shape == (2, cfg.vocab)
    tok = jnp.argmax(logits, -1)[:, None]
    for _ in range(2):
        logits, cache = mod.decode_step(cfg, params, cache, tok)
        assert bool(jnp.all(jnp.isfinite(logits)))
        tok = jnp.argmax(logits, -1)[:, None]


@pytest.mark.parametrize("arch", ["granite-8b", "qwen2-1.5b", "llama3-405b",
                                  "nemotron-4-15b", "mamba2-370m"])
def test_prefill_decode_consistency_exact_archs(arch):
    """For architectures without routing nondeterminism, prefill+decode
    must reproduce teacher-forced forward logits."""
    cfg = configs.get_smoke_config(arch)
    mod = model_api.get_model(cfg)
    params, _ = mod.init_params(cfg, jax.random.PRNGKey(0))
    toks = jax.random.randint(jax.random.PRNGKey(1), (2, 12), 0, cfg.vocab)
    full = mod.forward(cfg, params, toks)
    last, cache = mod.prefill(cfg, params, toks[:, :8], max_len=12)
    np.testing.assert_allclose(last, full[:, 7], atol=2e-4)
    ld, cache = mod.decode_step(cfg, params, cache, toks[:, 8:9])
    np.testing.assert_allclose(ld, full[:, 8], atol=2e-4)


def test_moe_consistency_no_drop():
    """With capacity ≥ group size the MoE drops nothing and routing is
    per-token — prefill/decode must match forward exactly."""
    cfg = configs.get_smoke_config("arctic-480b", capacity_factor=4.0)
    mod = model_api.get_model(cfg)
    params, _ = mod.init_params(cfg, jax.random.PRNGKey(0))
    toks = jax.random.randint(jax.random.PRNGKey(1), (2, 16), 0, cfg.vocab)
    full, _ = mod.forward(cfg, params, toks)
    last, cache = mod.prefill(cfg, params, toks[:, :8], max_len=16)
    np.testing.assert_allclose(last, full[:, 7], atol=3e-4)
    ld, _ = mod.decode_step(cfg, params, cache, toks[:, 8:9])
    np.testing.assert_allclose(ld, full[:, 8], atol=3e-4)


def test_mla_consistency_no_drop():
    cfg = configs.get_smoke_config("deepseek-v2-lite-16b", capacity_factor=4.0)
    mod = model_api.get_model(cfg)
    params, _ = mod.init_params(cfg, jax.random.PRNGKey(0))
    toks = jax.random.randint(jax.random.PRNGKey(1), (2, 16), 0, cfg.vocab)
    full, _ = mod.forward(cfg, params, toks)
    last, cache = mod.prefill(cfg, params, toks[:, :8], max_len=16)
    np.testing.assert_allclose(last, full[:, 7], atol=3e-4)
    # decode uses the *absorbed* latent path — must still match
    ld, _ = mod.decode_step(cfg, params, cache, toks[:, 8:9])
    np.testing.assert_allclose(ld, full[:, 8], atol=3e-4)


def test_moe_capacity_drops_tokens():
    """With tight capacity some tokens must be dropped (combine mass < 1)."""
    from repro.models import moe as moe_m

    cfg = configs.get_smoke_config("arctic-480b", capacity_factor=0.5)
    key = jax.random.PRNGKey(0)
    probs = jax.nn.softmax(
        jax.random.normal(key, (1, 64, cfg.n_experts)), -1
    )
    dispatch, combine = moe_m._topk_dispatch(cfg, probs)
    per_expert = jnp.sum(dispatch, axis=(1, 3))  # (G, E)
    C = max(int(cfg.capacity_factor * 64 * cfg.top_k / cfg.n_experts), 1)
    assert float(jnp.max(per_expert)) <= C
    assert float(jnp.sum(dispatch)) < 64 * cfg.top_k  # something dropped


def test_param_counts_match_config_estimates():
    """cfg.num_params() (used for MODEL_FLOPS) tracks actual param counts
    within 2% for every architecture."""
    for arch in ARCHS:
        cfg = configs.get_smoke_config(arch)
        mod = model_api.get_model(cfg)
        captured = {}

        def init(rng):
            p, a = mod.init_params(cfg, rng)
            captured["p"] = p
            return p

        sds = jax.eval_shape(init, jax.ShapeDtypeStruct((2,), jnp.uint32))
        actual = sum(int(np.prod(l.shape)) for l in jax.tree.leaves(sds))
        est = cfg.num_params()
        assert abs(actual - est) / actual < 0.02, (arch, actual, est)


def test_vlm_masks_patch_positions():
    cfg = configs.get_smoke_config("internvl2-2b")
    mod = model_api.get_model(cfg)
    params, _ = mod.init_params(cfg, jax.random.PRNGKey(0))
    b = _batch(cfg, S=16)
    # loss must not depend on labels at patch positions (they're excluded)
    l1 = mod.loss_fn(cfg, params, b)
    assert np.isfinite(float(l1))
    logits = mod.forward(cfg, params, b)
    assert logits.shape[1] == cfg.n_patches + 16
