"""Streaming video event search — the STHC's native serving mode.

Reference event clips ("what to look for") are recorded once into the
grating; a long video stream is then pushed through the coherence-window
segmentation (overlap-save, paper Fig. 1C) and each reference produces a
correlation peak wherever its event occurs.

The server is multi-tenant: every named reference kernel set shares one
grating cache with an LRU budget in entries and bytes, and each query
routes to its tenant's grating (re-recorded transparently if evicted).
Fidelity mode is a per-server property (one STHC config per server), so
the demo runs two tenants — action-class references plus their negation
— on one *ideal*-mode server sharing a cache, then repeats the search
through the full *physical* model on a second server; the stream hides
one 'running' clip among distractors that both must localize.

Run:  PYTHONPATH=src python examples/serve_video.py
"""

import jax.numpy as jnp
import numpy as np

from repro.data import kth_synthetic as kth
from repro.launch.serve import VideoSearchConfig, VideoSearchServer

SPEC = kth.VideoSpec(height=24, width=32, frames=12)


def main() -> None:
    # reference events: one exemplar per action class (subject 20 — unseen)
    refs = np.stack(
        [kth.render_clip(label, 20, 0, SPEC) for label in range(4)]
    )[:, None]  # (4, 1, H, W, T)
    refs = refs - refs.mean(axis=(2, 3, 4), keepdims=True)  # zero-mean match
    refs = jnp.asarray(refs.astype(np.float32))

    # a long stream: waving ... running ... boxing (subject 21, unseen)
    segments = [kth.render_clip(1, 21, 1, SPEC), kth.render_clip(3, 21, 1, SPEC),
                kth.render_clip(2, 21, 1, SPEC)]
    stream = np.concatenate(segments, axis=-1)[None, None]  # (1,1,H,W,3T)
    stream = jnp.asarray(stream.astype(np.float32))

    # The references are recorded into the shared grating cache once, at
    # add_tenant time; every subsequent search diffracts off the same
    # stored spectrum (record-once / stream-forever).  chunk_windows
    # batches the coherence windows through vmap'd FFTs instead of a
    # strictly sequential scan.
    server = VideoSearchServer(
        frame_hw=(SPEC.height, SPEC.width),
        cfg=VideoSearchConfig(window_frames=24, chunk_windows=2),
    )
    server.add_tenant("actions", refs)
    server.add_tenant("actions-negated", -refs)  # a second reference set

    out = server.search(stream, tenant="actions")
    print(f"stream of {stream.shape[-1]} frames searched in "
          f"{out['windows']} coherence windows "
          f"({out['latency_s']*1000:.0f} ms)")
    names = kth.CLASSES
    scores = out["scores"][0]
    peaks = out["peak_frame"][0]
    for i, name in enumerate(names):
        print(f"  reference '{name:9s}': score {scores[i]:7.2f} "
              f"peak at frame {peaks[i]:3d}")
    # localization check: the 'running' reference must peak inside the
    # running segment (frames 12..23 of the stream)
    run_peak = int(peaks[3])
    ok = 12 - SPEC.frames // 2 <= run_peak <= 23
    print(f"'running' reference localizes the running segment "
          f"(frames 12-23): peak {run_peak} -> {'OK' if ok else 'MISS'}")

    # the same search through the full physical model (SLM quantization,
    # ± channels, IHB/T2 envelopes, stream-global SLM scale) — the
    # engine's one streaming path serves both fidelity modes.
    phys = VideoSearchServer(
        frame_hw=(SPEC.height, SPEC.width),
        cfg=VideoSearchConfig(window_frames=24, chunk_windows=2,
                              mode="physical"),
    )
    phys.add_tenant("actions", refs)
    pout = phys.search(stream, tenant="actions")
    print(f"physical-mode 'running' score {pout['scores'][0][3]:7.2f} "
          f"(ideal {scores[3]:7.2f}), peak at frame {pout['peak_frame'][0][3]}")

    # serving metrics: cache behavior + measured vs projected rates
    m = server.metrics()
    c = m["cache"]
    print(f"cache: {c['hits']} hits / {c['misses']} misses / "
          f"{c['evictions']} evictions, {c['entries']} gratings "
          f"({c['bytes']/1e6:.2f} MB resident)")
    print(f"throughput: {m['frames_per_s']:.0f} frames/s measured on this "
          f"host vs {m['projected_slm_fps']:.0f} fps (SLM) / "
          f"{m['projected_hmd_fps']:.0f} fps (HMD) projected loaders")


if __name__ == "__main__":
    main()
