"""Streaming video event search — the STHC's native serving mode.

Reference event clips ("what to look for") are recorded once into the
grating; a long video stream is then pushed through the coherence-window
segmentation (overlap-save, paper Fig. 1C) and each reference produces a
correlation peak wherever its event occurs.

The server is multi-tenant *and mixed-fidelity*: every named reference
kernel set (tenant) registers with its own fidelity pipeline — the
ordered stack of physics stages from :mod:`repro.core.fidelity` — and
all of them share one grating cache with an LRU budget in entries and
bytes (each query routes to its tenant's grating, re-recorded
transparently if evicted; the cache key's pipeline fingerprint keeps
fidelities apart).  The demo registers the same action-class references
three times on ONE server: through the exact *ideal* correlator, the
full *physical* model, and a quantization-only stage subset; the stream
hides one 'running' clip among distractors all three must localize.

Run:  PYTHONPATH=src python examples/serve_video.py
"""

import jax.numpy as jnp
import numpy as np

from repro.core import fidelity
from repro.data import kth_synthetic as kth
from repro.launch.serve import VideoSearchConfig, VideoSearchServer

SPEC = kth.VideoSpec(height=24, width=32, frames=12)


def main() -> None:
    # reference events: one exemplar per action class (subject 20 — unseen)
    refs = np.stack(
        [kth.render_clip(label, 20, 0, SPEC) for label in range(4)]
    )[:, None]  # (4, 1, H, W, T)
    refs = refs - refs.mean(axis=(2, 3, 4), keepdims=True)  # zero-mean match
    refs = jnp.asarray(refs.astype(np.float32))

    # a long stream: waving ... running ... boxing (subject 21, unseen)
    segments = [kth.render_clip(1, 21, 1, SPEC), kth.render_clip(3, 21, 1, SPEC),
                kth.render_clip(2, 21, 1, SPEC)]
    stream = np.concatenate(segments, axis=-1)[None, None]  # (1,1,H,W,3T)
    stream = jnp.asarray(stream.astype(np.float32))

    # The references are recorded into the shared grating cache once, at
    # registration time; every subsequent search diffracts off the same
    # stored spectrum (record-once / stream-forever).  chunk_windows
    # batches the coherence windows through vmap'd FFTs instead of a
    # strictly sequential scan.  Fidelity is per *kernel set*: one
    # server, one cache, three pipelines — the cache key's pipeline
    # fingerprint keeps the gratings apart even though the kernel bytes
    # are identical.
    server = VideoSearchServer(
        frame_hw=(SPEC.height, SPEC.width),
        cfg=VideoSearchConfig(window_frames=24, chunk_windows=2),
    )
    server.add_kernel_set("actions", refs)  # server default: ideal()
    server.add_kernel_set("actions-physical", refs,
                          fidelity=fidelity.physical())
    server.add_kernel_set(
        "actions-slm-only", refs,
        fidelity=fidelity.pipeline(fidelity.SLMQuantize(), name="slm-only"),
    )

    out = server.search(stream, tenant="actions")
    print(f"stream of {stream.shape[-1]} frames searched in "
          f"{out['windows']} coherence windows "
          f"({out['latency_s']*1000:.0f} ms)")
    names = kth.CLASSES
    scores = out["scores"][0]
    peaks = out["peak_frame"][0]
    for i, name in enumerate(names):
        print(f"  reference '{name:9s}': score {scores[i]:7.2f} "
              f"peak at frame {peaks[i]:3d}")
    # localization check: the 'running' reference must peak inside the
    # running segment (frames 12..23 of the stream)
    run_peak = int(peaks[3])
    ok = 12 - SPEC.frames // 2 <= run_peak <= 23
    print(f"'running' reference localizes the running segment "
          f"(frames 12-23): peak {run_peak} -> {'OK' if ok else 'MISS'}")

    # the same stream through the other two fidelities — same server,
    # same shared cache, per-tenant physics (one streaming engine path).
    for tenant in ("actions-physical", "actions-slm-only"):
        tout = server.search(stream, tenant=tenant)
        fid_name = server.metrics()["tenants"][tenant]["fidelity"]
        print(f"[{fid_name:9s}] 'running' score {tout['scores'][0][3]:7.2f} "
              f"(ideal {scores[3]:7.2f}), "
              f"peak at frame {tout['peak_frame'][0][3]}")

    # serving metrics: cache behavior + measured vs projected rates
    m = server.metrics()
    c = m["cache"]
    print(f"cache: {c['hits']} hits / {c['misses']} misses / "
          f"{c['evictions']} evictions, {c['entries']} gratings "
          f"({c['bytes']/1e6:.2f} MB resident) — "
          f"{len(set(t['fidelity'] for t in m['tenants'].values()))} "
          f"fidelities on one server")
    print(f"throughput: {m['frames_per_s']:.0f} frames/s measured on this "
          f"host vs {m['projected_slm_fps']:.0f} fps (SLM) / "
          f"{m['projected_hmd_fps']:.0f} fps (HMD) projected loaders")


if __name__ == "__main__":
    main()
