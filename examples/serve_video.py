"""Streaming video event search — the STHC's native serving mode.

Reference event clips ("what to look for") are recorded once into the
grating; a long video stream is then pushed through the coherence-window
segmentation (overlap-save, paper Fig. 1C) and each reference produces a
correlation peak wherever its event occurs.

The server is multi-tenant *and mixed-fidelity*: every named reference
kernel set (tenant) registers with its own fidelity pipeline — the
ordered stack of physics stages from :mod:`repro.core.fidelity` — and
all of them share one grating cache with an LRU budget in entries and
bytes (each query routes to its tenant's grating, re-recorded
transparently if evicted; the cache key's pipeline fingerprint keeps
fidelities apart).  The demo registers the same action-class references
three times on ONE server: through the exact *ideal* correlator, the
full *physical* model, and a quantization-only stage subset; the stream
hides one 'running' clip among distractors all three must localize.

Detection is served by the **fused in-kernel readout**
(``VideoSearchConfig.fused_readout``, on by default): each coherence
window chunk's correlation scores collapse in-kernel to the K best
(score, position) pairs per reference, so the full correlation volume
never materializes — constant output-side memory at any stream length,
bitwise equal to the stitched volume's max/argmax.  Related knobs:
``readout_topk`` reports the K best detections per reference
(``topk_scores`` / ``topk_frames`` in the result), ``readout_block_o`` /
``readout_block_l`` tune the Pallas readout tiles on real hardware, and
``search(..., return_volume=True)`` opts one call back into the stitched
volume when the caller needs the raw correlation map.

The production front door is the **async microbatch scheduler**
(queue → batcher → pooled executor): callers submit requests and get
futures, the scheduler coalesces concurrent mixed-tenant requests into
microbatches, and same-geometry tenants are answered from one pooled
grating arena in a single device dispatch.  The demo pushes the same
stream through all three fidelities concurrently that way and prints
the scheduler's latency percentiles and batch counters.

Run:  PYTHONPATH=src python examples/serve_video.py
"""

import jax.numpy as jnp
import numpy as np

from repro.core import fidelity
from repro.data import kth_synthetic as kth
from repro.launch.serve import (
    MicrobatchScheduler,
    VideoSearchConfig,
    VideoSearchServer,
)

SPEC = kth.VideoSpec(height=24, width=32, frames=12)


def main() -> None:
    # reference events: one exemplar per action class (subject 20 — unseen)
    refs = np.stack(
        [kth.render_clip(label, 20, 0, SPEC) for label in range(4)]
    )[:, None]  # (4, 1, H, W, T)
    refs = refs - refs.mean(axis=(2, 3, 4), keepdims=True)  # zero-mean match
    refs = jnp.asarray(refs.astype(np.float32))

    # a long stream: waving ... running ... boxing (subject 21, unseen)
    segments = [kth.render_clip(1, 21, 1, SPEC), kth.render_clip(3, 21, 1, SPEC),
                kth.render_clip(2, 21, 1, SPEC)]
    stream = np.concatenate(segments, axis=-1)[None, None]  # (1,1,H,W,3T)
    stream = jnp.asarray(stream.astype(np.float32))

    # The references are recorded into the shared grating cache once, at
    # registration time; every subsequent search diffracts off the same
    # stored spectrum (record-once / stream-forever).  chunk_windows
    # batches the coherence windows through vmap'd FFTs instead of a
    # strictly sequential scan.  Fidelity is per *kernel set*: one
    # server, one cache, three pipelines — the cache key's pipeline
    # fingerprint keeps the gratings apart even though the kernel bytes
    # are identical.
    server = VideoSearchServer(
        frame_hw=(SPEC.height, SPEC.width),
        cfg=VideoSearchConfig(window_frames=24, chunk_windows=2),
    )
    server.add_kernel_set("actions", refs)  # server default: ideal()
    server.add_kernel_set("actions-physical", refs,
                          fidelity=fidelity.physical())
    server.add_kernel_set(
        "actions-slm-only", refs,
        fidelity=fidelity.pipeline(fidelity.SLMQuantize(), name="slm-only"),
    )

    out = server.search(stream, tenant="actions")
    print(f"stream of {stream.shape[-1]} frames searched in "
          f"{out['windows']} coherence windows "
          f"({out['latency_s']*1000:.0f} ms)")
    names = kth.CLASSES
    scores = out["scores"][0]
    peaks = out["peak_frame"][0]
    for i, name in enumerate(names):
        print(f"  reference '{name:9s}': score {scores[i]:7.2f} "
              f"peak at frame {peaks[i]:3d}")
    # localization check: the 'running' reference must peak inside the
    # running segment (frames 12..23 of the stream)
    run_peak = int(peaks[3])
    ok = 12 - SPEC.frames // 2 <= run_peak <= 23
    print(f"'running' reference localizes the running segment "
          f"(frames 12-23): peak {run_peak} -> {'OK' if ok else 'MISS'}")

    # the scores above came from the fused readout (no correlation
    # volume was ever built); opting one call back into the stitched
    # volume shows they are bitwise the volume's max — and a top-3
    # server reports the runner-up detections per reference
    vol_out = server.search(stream, tenant="actions", return_volume=True)
    exact = bool(np.array_equal(out["scores"], vol_out["scores"]))
    print(f"fused readout == stitched volume max: {exact} "
          f"(a {'x'.join(str(d) for d in vol_out['volume'].shape)} "
          f"volume avoided per search; the gap grows with stream "
          f"length and references)")
    topk_server = VideoSearchServer(
        frame_hw=(SPEC.height, SPEC.width),
        cfg=VideoSearchConfig(
            window_frames=24, chunk_windows=2, readout_topk=3
        ),
    )
    topk_server.add_kernel_set("actions", refs)
    t3 = topk_server.search(stream, tenant="actions")
    frames3 = ", ".join(str(f) for f in t3["topk_frames"][0][3])
    print(f"top-3 'running' detections peak at frames [{frames3}]")

    # the same stream through all three fidelities *concurrently*, via
    # the async microbatch front end: submit returns futures, the
    # scheduler coalesces the requests into one microbatch, and the
    # pooled executor answers every same-geometry tenant from one
    # grating arena in a single device dispatch.
    with MicrobatchScheduler(
        server, max_queue=16, max_batch=8, batch_wait_s=0.01
    ) as sched:
        futs = {
            tenant: sched.submit(tenant, stream)
            for tenant in ("actions-physical", "actions-slm-only")
        }
        for tenant, fut in futs.items():
            tout = fut.result(timeout=120)
            fid_name = server.metrics()["tenants"][tenant]["fidelity"]
            print(
                f"[{fid_name:9s}] 'running' score "
                f"{tout['scores'][0][3]:7.2f} (ideal {scores[3]:7.2f}), "
                f"peak at frame {tout['peak_frame'][0][3]}, "
                f"end-to-end {tout['queue_latency_s'] * 1e3:.0f} ms"
            )
        sm = sched.metrics()
    print(
        f"scheduler: {sm['completed']} served in {sm['batches']} "
        f"microbatches (mean size {sm['mean_batch_size']:.1f}), "
        f"p50 {sm['latency_p50_ms']:.0f} ms / p99 "
        f"{sm['latency_p99_ms']:.0f} ms, {sm['rejected']} shed"
    )

    # serving metrics: cache behavior + measured vs projected rates
    m = server.metrics()
    c = m["cache"]
    print(f"cache: {c['hits']} hits / {c['misses']} misses / "
          f"{c['evictions']} evictions, {c['entries']} gratings "
          f"({c['bytes']/1e6:.2f} MB resident) — "
          f"{len(set(t['fidelity'] for t in m['tenants'].values()))} "
          f"fidelities on one server")
    print(f"throughput: {m['frames_per_s']:.0f} frames/s measured on this "
          f"host vs {m['projected_slm_fps']:.0f} fps (SLM) / "
          f"{m['projected_hmd_fps']:.0f} fps (HMD) projected loaders")


if __name__ == "__main__":
    main()
