"""Streaming video event search — the STHC's native serving mode.

Reference event clips ("what to look for") are recorded once into the
grating; a long video stream is then pushed through the coherence-window
segmentation (overlap-save, paper Fig. 1C) and each reference produces a
correlation peak wherever its event occurs.

Here the stream hides one 'running' clip among distractors; the server
must localize it in time.

Run:  PYTHONPATH=src python examples/serve_video.py
"""

import jax.numpy as jnp
import numpy as np

from repro.data import kth_synthetic as kth
from repro.launch.serve import VideoSearchConfig, VideoSearchServer

SPEC = kth.VideoSpec(height=24, width=32, frames=12)


def main() -> None:
    # reference events: one exemplar per action class (subject 20 — unseen)
    refs = np.stack(
        [kth.render_clip(label, 20, 0, SPEC) for label in range(4)]
    )[:, None]  # (4, 1, H, W, T)
    refs = refs - refs.mean(axis=(2, 3, 4), keepdims=True)  # zero-mean match

    # a long stream: waving ... running ... boxing (subject 21, unseen)
    segments = [kth.render_clip(1, 21, 1, SPEC), kth.render_clip(3, 21, 1, SPEC),
                kth.render_clip(2, 21, 1, SPEC)]
    stream = np.concatenate(segments, axis=-1)[None, None]  # (1,1,H,W,3T)

    # The references are recorded into the grating once, here; every
    # subsequent search diffracts off the same stored spectrum
    # (record-once / query-many).  chunk_windows batches the coherence
    # windows through vmap'd FFTs instead of a strictly sequential scan.
    server = VideoSearchServer(
        jnp.asarray(refs.astype(np.float32)),
        (SPEC.height, SPEC.width),
        VideoSearchConfig(window_frames=24, chunk_windows=2),
    )
    out = server.search(jnp.asarray(stream.astype(np.float32)))
    print(f"stream of {stream.shape[-1]} frames searched in "
          f"{out['windows']} coherence windows "
          f"({out['latency_s']*1000:.0f} ms)")
    names = kth.CLASSES
    scores = out["scores"][0]
    peaks = out["peak_frame"][0]
    for i, name in enumerate(names):
        print(f"  reference '{name:9s}': score {scores[i]:7.2f} "
              f"peak at frame {peaks[i]:3d}")
    # localization check: the 'running' reference must peak inside the
    # running segment (frames 12..23 of the stream)
    run_peak = int(peaks[3])
    ok = 12 - SPEC.frames // 2 <= run_peak <= 23
    print(f"'running' reference localizes the running segment "
          f"(frames 12-23): peak {run_peak} -> {'OK' if ok else 'MISS'}")


if __name__ == "__main__":
    main()
