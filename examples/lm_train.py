"""Train an assigned-architecture LM (reduced config) with the full
framework stack: sharded train step, AdamW + cosine schedule, gradient
compression (optional), atomic async checkpoints, kill-safe resume.

Run:  PYTHONPATH=src python examples/lm_train.py --arch mamba2-370m \
          --steps 60 --ckpt-dir /tmp/lm_ckpt
Re-run the same command to watch it resume from the latest checkpoint.
"""

import argparse

from repro import configs
from repro.launch.train import TrainConfig, train_loop
from repro.optim import AdamWConfig


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="mamba2-370m")
    ap.add_argument("--steps", type=int, default=60)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=64)
    ap.add_argument("--ckpt-dir", default="/tmp/lm_ckpt")
    ap.add_argument("--compress-grads", action="store_true")
    args = ap.parse_args()

    cfg = configs.get_smoke_config(args.arch)
    tc = TrainConfig(
        steps=args.steps, batch=args.batch, seq=args.seq,
        save_every=max(args.steps // 5, 1),
        compress_grads=args.compress_grads,
    )
    out = train_loop(cfg, tc, args.ckpt_dir, opt_cfg=AdamWConfig(lr=1e-3))
    print(f"[{args.arch}] done: loss {out['loss']:.4f} after "
          f"{out['steps_done']} steps (ckpts in {args.ckpt_dir})")


if __name__ == "__main__":
    main()
