"""Quickstart: the STHC in five minutes.

1. build a correlator, record kernels into the atomic grating,
2. correlate a video clip — the ideal pipeline matches digital convolution,
3. the physical pipeline shows the (small) cost of real atoms + SLM —
   and any *subset* of its stages isolates one effect,
4. one hybrid-CNN training step.

Run:  PYTHONPATH=src python examples/quickstart.py
"""

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import fidelity, hybrid, spectral_conv
from repro.core.sthc import STHC, STHCConfig

rng = np.random.RandomState(0)

# a clip (batch 2, 1 channel, 36×48 px, 12 frames) and 4 learned kernels
clip = jnp.asarray(rng.rand(2, 1, 36, 48, 12).astype(np.float32))
kernels = jnp.asarray(rng.randn(4, 1, 12, 16, 6).astype(np.float32))

# --- 1+2: ideal correlator ≡ digital 3-D convolution -----------------
sthc = STHC(STHCConfig(fidelity=fidelity.ideal()))
grating = sthc.record(kernels, clip.shape[-3:])  # 'store' in the atoms
feature_maps = sthc.correlate(grating, clip)  # 'diffract' the query
ref = spectral_conv.direct_correlate3d(clip, kernels, "valid")
print(f"feature maps {feature_maps.shape}, "
      f"ideal-vs-digital max err {float(jnp.max(jnp.abs(feature_maps - ref))):.2e}")

# --- 3: the physical pipeline (8-bit SLM, ± channels, IHB, T2, echo) --
phys = STHC(STHCConfig(fidelity=fidelity.physical()))
y_phys = phys(kernels, clip)
rel = float(jnp.linalg.norm(y_phys - ref) / jnp.linalg.norm(ref))
print(f"physical-pipeline relative error: {rel:.1%}  (the paper's "
      "accuracy drop comes from effects like these)")

# ... and fidelity is composable: any stage subset isolates one effect.
# Here, SLM quantization alone — the first rung of the paper's
# degradation decomposition (benchmarks/ablation.py sweeps them all).
quant_only = STHC(STHCConfig(
    fidelity=fidelity.pipeline(fidelity.SLMQuantize(), name="slm-only")
))
y_q = quant_only(kernels, clip)
rel_q = float(jnp.linalg.norm(y_q - ref) / jnp.linalg.norm(ref))
print(f"SLM-quantization-only relative error: {rel_q:.2%} "
      "(one stage of the stack above)")

# --- 4: one hybrid-CNN training step ----------------------------------
cfg = hybrid.HybridConfig(height=36, width=48, frames=12, k_h=12, k_w=16,
                          k_t=6, num_kernels=4, pool_window=(6, 8, 3),
                          hidden=32)
params = hybrid.init_params(jax.random.PRNGKey(0), cfg)
batch = {"video": clip, "label": jnp.asarray([0, 1])}
loss, aux = hybrid.loss_fn(params, batch, cfg, impl="spectral")
print(f"hybrid CNN initial loss: {float(loss):.3f} (ln 4 = 1.386)")
grads = jax.grad(lambda p: hybrid.loss_fn(p, batch, cfg, impl="spectral")[0])(params)
print("gradient flows through the optical layer:",
      bool(jnp.any(grads["conv_w"] != 0)))
