"""End-to-end driver — the paper's §4.1 experiment, full geometry.

Trains the single-conv-layer hybrid 3-D CNN (9 kernels, 30×40×8) on the
synthetic KTH action dataset for a few hundred steps, then evaluates the
subject-held-out test split with the conv layer served by:
  * the digital baseline,
  * the ideal STHC (must match), and
  * the physical STHC (SLM quantization + pseudo-negative + atomic
    envelopes) — the paper's hybrid deployment.

Run:  PYTHONPATH=src python examples/video_classification.py [--fast]
"""

import argparse
import sys
import time

sys.path.insert(0, ".")  # allow `benchmarks` import when run from repo root

from benchmarks import accuracy


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--fast", action="store_true")
    ap.add_argument("--epochs", type=int, default=None)
    args = ap.parse_args()
    epochs = args.epochs or (8 if args.fast else 40)
    t0 = time.time()
    rows = accuracy.run(epochs=epochs, full_geometry=not args.fast, log=print)
    print(f"\n--- results ({time.time() - t0:.0f}s) ---")
    for r in rows:
        name, _, val = r.split(",")
        print(f"{name:40s} {val}")


if __name__ == "__main__":
    main()
