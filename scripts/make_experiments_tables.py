"""Generate the EXPERIMENTS.md §Dry-run / §Roofline tables from the
dry-run JSON records."""

import glob
import json
import os
import sys

SHAPE_ORDER = ["train_4k", "prefill_32k", "decode_32k", "long_500k"]
ARCH_ORDER = [
    "granite-8b", "qwen2-1.5b", "llama3-405b", "nemotron-4-15b",
    "mamba2-370m", "zamba2-2.7b", "arctic-480b", "deepseek-v2-lite-16b",
    "whisper-tiny", "internvl2-2b",
]

PEAK = 197e12


def load(dryrun_dir):
    recs = {}
    for p in glob.glob(os.path.join(dryrun_dir, "*.json")):
        r = json.load(open(p))
        recs[(r["arch"], r["shape"], r["mesh"], r.get("variant", "baseline"))] = r
    return recs


def fmt_s(x):
    return f"{x:.4f}" if x < 1 else f"{x:.1f}"


def roofline_table(recs, mesh="pod16x16", variant="baseline"):
    lines = [
        "| arch | shape | compute (s) | memory (s) | collective (s) | bottleneck "
        "| MODEL_FLOPS | useful ratio | roofline frac |",
        "|---|---|---|---|---|---|---|---|---|",
    ]
    for arch in ARCH_ORDER:
        for shape in SHAPE_ORDER:
            r = recs.get((arch, shape, mesh, variant))
            if r is None:
                continue
            if r["status"] == "skipped":
                lines.append(
                    f"| {arch} | {shape} | — | — | — | *skipped: full-attention "
                    f"arch at 500k (DESIGN.md §Arch-applicability)* | | | |"
                )
                continue
            rl = r["roofline"]
            ideal = rl["model_flops"] / (r["n_chips"] * PEAK)
            bott = max(rl["compute_s"], rl["memory_s"], rl["collective_s"])
            frac = ideal / bott if bott else 0.0
            lines.append(
                f"| {arch} | {shape} | {fmt_s(rl['compute_s'])} | "
                f"{fmt_s(rl['memory_s'])} | {fmt_s(rl['collective_s'])} | "
                f"**{rl['bottleneck']}** | {rl['model_flops']:.2e} | "
                f"{rl['useful_flops_ratio']:.2f} | {frac:.1%} |"
            )
    return "\n".join(lines)


def dryrun_table(recs):
    lines = [
        "| arch | shape | 16×16 compile | 2×16×16 compile | collectives (single-pod) |",
        "|---|---|---|---|---|",
    ]
    for arch in ARCH_ORDER:
        for shape in SHAPE_ORDER:
            r1 = recs.get((arch, shape, "pod16x16", "baseline"))
            r2 = recs.get((arch, shape, "pod2x16x16", "baseline"))
            if r1 is None and r2 is None:
                continue
            if r1 and r1["status"] == "skipped":
                lines.append(f"| {arch} | {shape} | skip | skip | — |")
                continue

            def cstat(r):
                if r is None:
                    return "?"
                return f"ok ({r['compile_s']}s)" if r["status"] == "ok" else r["status"]

            coll = ""
            if r1 and r1["status"] == "ok":
                cc = r1["roofline"]["collective_counts"]
                coll = ", ".join(f"{k}×{v}" for k, v in sorted(cc.items()))
            lines.append(
                f"| {arch} | {shape} | {cstat(r1)} | {cstat(r2)} | {coll} |"
            )
    return "\n".join(lines)


def variants_table(recs, arch, shape, mesh="pod16x16"):
    lines = [
        "| variant | compute (s) | memory (s) | collective (s) | bottleneck | temp (CPU-f32 GB) |",
        "|---|---|---|---|---|---|",
    ]
    for (a, s, m, v), r in sorted(recs.items()):
        if (a, s, m) != (arch, shape, mesh) or r["status"] != "ok":
            continue
        rl = r["roofline"]
        temp = r.get("memory_analysis", {}).get("temp_size_in_bytes", 0) / 1e9
        lines.append(
            f"| {v} | {fmt_s(rl['compute_s'])} | {fmt_s(rl['memory_s'])} | "
            f"{fmt_s(rl['collective_s'])} | {rl['bottleneck']} | {temp:.0f} |"
        )
    return "\n".join(lines)


if __name__ == "__main__":
    recs = load(sys.argv[1] if len(sys.argv) > 1 else "experiments/dryrun")
    print("## Roofline (single-pod 16×16, baseline)\n")
    print(roofline_table(recs))
    print("\n## Dry-run status\n")
    print(dryrun_table(recs))
    for arch, shape in (
        ("llama3-405b", "train_4k"),
        ("qwen2-1.5b", "train_4k"),
        ("arctic-480b", "train_4k"),
    ):
        print(f"\n## Variants: {arch} × {shape}\n")
        print(variants_table(recs, arch, shape))
