#!/usr/bin/env python
"""repro-lint CLI.

Usage:
    python scripts/lint.py [paths...]          # default: src benchmarks
    python scripts/lint.py --format json --output ci-lint/report.json src benchmarks
    python scripts/lint.py --changed           # only files changed vs origin/main
    python scripts/lint.py --self-test         # seeded fixtures must fire every rule

Exit status: 0 when no *unsuppressed* findings, 1 otherwise (and for a
failed --self-test).  Pure stdlib -- no jax import, so --changed stays
sub-second in the pre-push loop.
"""

from __future__ import annotations

import argparse
import os
import subprocess
import sys

_REPO_ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
sys.path.insert(0, os.path.join(_REPO_ROOT, "src"))

from repro.analysis import RULES, format_json, format_text, run_lint  # noqa: E402


def _changed_files() -> list:
    """Python files changed vs origin/main (falls back to main, then HEAD)."""
    for base in ("origin/main", "main", "HEAD"):
        try:
            out = subprocess.run(
                ["git", "diff", "--name-only", "--diff-filter=d", base, "--"],
                cwd=_REPO_ROOT,
                capture_output=True,
                text=True,
                check=True,
            ).stdout
        except (subprocess.CalledProcessError, OSError):
            continue
        files = [
            os.path.join(_REPO_ROOT, line.strip())
            for line in out.splitlines()
            if line.strip().endswith(".py")
        ]
        return [f for f in files if os.path.exists(f) and _in_scope(f)]
    return []


def _in_scope(path: str) -> bool:
    rel = os.path.relpath(path, _REPO_ROOT)
    return rel.startswith(("src" + os.sep, "benchmarks" + os.sep))


def _self_test() -> int:
    """Run on the seeded-violation fixtures: every rule must fire there,
    and every suppressed seed must stay suppressed.  Proves the CI gate
    can actually fail."""
    fixtures = os.path.join(_REPO_ROOT, "tests", "lint_fixtures")
    if not os.path.isdir(fixtures):
        print(f"repro-lint --self-test: fixture dir missing: {fixtures}")
        return 1
    findings = run_lint([fixtures], root=_REPO_ROOT)
    active_rules = {f.rule for f in findings if not f.suppressed}
    suppressed_rules = {f.rule for f in findings if f.suppressed}
    missing_fire = sorted(set(RULES) - active_rules)
    missing_suppress = sorted(set(RULES) - suppressed_rules)
    ok = True
    if missing_fire:
        print(f"repro-lint --self-test: rules that did NOT fire: {missing_fire}")
        ok = False
    if missing_suppress:
        print(
            "repro-lint --self-test: rules without a working suppression "
            f"seed: {missing_suppress}"
        )
        ok = False
    print(
        f"repro-lint --self-test: {len(active_rules)}/{len(RULES)} rules fired, "
        f"{len(suppressed_rules)}/{len(RULES)} suppression seeds held "
        f"({'OK' if ok else 'FAIL'})"
    )
    return 0 if ok else 1


def main(argv=None) -> int:
    parser = argparse.ArgumentParser(prog="repro-lint", description=__doc__)
    parser.add_argument("paths", nargs="*", help="files or directories (default: src benchmarks)")
    parser.add_argument("--format", choices=("text", "json"), default="text")
    parser.add_argument("--output", help="write the report to this file instead of stdout")
    parser.add_argument(
        "--changed",
        action="store_true",
        help="lint only in-scope .py files changed vs origin/main",
    )
    parser.add_argument(
        "--self-test",
        action="store_true",
        help="lint the seeded-violation fixtures; fail unless every rule fires",
    )
    parser.add_argument(
        "--show-suppressed",
        action="store_true",
        help="include suppressed findings in text output",
    )
    args = parser.parse_args(argv)

    if args.self_test:
        return _self_test()

    if args.changed:
        paths = _changed_files()
        if not paths:
            print("repro-lint: no changed in-scope files")
            return 0
    else:
        paths = args.paths or [
            os.path.join(_REPO_ROOT, "src"),
            os.path.join(_REPO_ROOT, "benchmarks"),
        ]

    findings = run_lint(paths, root=_REPO_ROOT)
    if args.format == "json":
        report = format_json(findings)
    else:
        report = format_text(findings, verbose_suppressed=args.show_suppressed)
    if args.output:
        os.makedirs(os.path.dirname(args.output) or ".", exist_ok=True)
        with open(args.output, "w", encoding="utf-8") as fh:
            fh.write(report + "\n")
        # A written artifact still prints the one-line summary.
        active = sum(1 for f in findings if not f.suppressed)
        sup = sum(1 for f in findings if f.suppressed)
        print(f"repro-lint: {active} finding(s), {sup} suppressed -> {args.output}")
    else:
        print(report)
    return 1 if any(not f.suppressed for f in findings) else 0


if __name__ == "__main__":
    sys.exit(main())
