"""Perf-regression gate: fresh smoke ``BENCH_*.json`` vs the committed
baselines.

CI runs the benchmark smokes per push and this gate compares the fresh
artifacts against the baselines committed at the repo root, failing the
build when a *key* metric regresses by more than the tolerance (default
25 %).  The gated metrics are chosen to be **machine-portable**: ratio
rows (pooled-vs-sequential speedup, fused-vs-unfused speedup, the
shared-stream clip-dedup speedup, the bf16 capacity factor) and
correctness-scale values (bf16 score error, chunked-streaming score
error, the constant peak-buffer bound) rather than absolute latencies —
a CI runner is not the machine the baselines were recorded on, but the
*structure* of the win (how much the pooled path beats the sequential
one, that bf16 really halves bytes, that chunking stays exact) should
survive any host.

Metric direction is per-spec: ``higher`` metrics fail when the fresh
value drops more than ``tol`` below baseline; ``lower`` metrics
(errors, overheads) fail when it rises more than ``tol`` above; ``eq``
metrics (the peak-buffer bound) fail on any change beyond float fuzz.
A few metrics additionally carry **absolute floors** (``FLOORS``) —
acceptance invariants like the fused-readout memory shrink (≥4×) and
throughput parity (≥0.95×) that must hold outright, not merely not
regress; the committed baseline is held to the raw floor, the fresh
run to floor − slack.
Rows missing from the *baseline* are reported and skipped, so a PR that
adds a new benchmark row does not need a same-PR baseline.  Rows
missing from the *fresh* run are loud WARNINGS by default — CI runs the
suites in separate jobs (serving in the bench smoke, chaos in its own
chaos-smoke), and each job's fresh dir legitimately lacks the other
suite's rows; pass ``--strict`` when the fresh dir is expected to carry
every gated row (a full local run) and missing rows should fail.

Run (CI wires this after the smoke steps)::

    python scripts/bench_gate.py --fresh-dir ci-bench --baseline-dir . \
        [--tolerance 0.25] [--strict]

Exit code 0 = all gated metrics within tolerance, 1 = regression.
"""

from __future__ import annotations

import argparse
import os
import sys

sys.path.insert(0, os.path.dirname(os.path.abspath(__file__)))
import plot_bench  # noqa: E402  (shares the BENCH_*.json row parsing)

# metric name (from plot_bench.TRACKED or local SPECS) -> direction
# higher = regression when fresh < baseline * (1 - tol)
# lower  = regression when fresh > baseline * (1 + tol)
# eq     = regression when |fresh - baseline| > eps (structural invariants)
GATED = {
    # the headline speedups — ISSUE/ROADMAP acceptance rows
    "serving_pooled_vs_seq_x": "higher",
    "fused_vs_unfused_x": "higher",
    "serving_shared_dedup_x": "higher",
    "serving_bf16_capacity_x": "higher",
    # throughput ratio of the pooled path (windows/s is absolute, so the
    # gate compares pooled/sequential measured on the SAME host)
    "serving_pooled_over_seq_winps": "higher",
    # correctness-scale values: must not drift up
    "serving_bf16_score_err": "lower",
    "serving_chunked_score_err": "lower",
    "serving_chunked_overhead_x": "lower",
    # structural invariant: the bounded-memory peak buffer is geometry,
    # not performance — any change is a real behavior change
    "serving_chunked_peak_frames": "eq",
    # fused in-kernel detection readout: the throughput ratio is
    # same-host, and exactness is bitwise — fused scores/frames must
    # equal the stitched volume's max/argmax, so the error row is
    # structurally 0.  The memory shrink is gated by its absolute
    # FLOOR only: it grows with stream length, and the CI smoke runs a
    # shorter stream than the committed full-run baseline, so a
    # baseline-relative check would structurally fail.
    "serving_fused_winps_x": "higher",
    "serving_fused_exact_err": "lower",
    "serving_fused_frame_mismatches": "eq",
    # chaos/availability suite: healthy fraction under the fault storm
    # (the poisoned-clip count is deterministic, so this is stable),
    # the resolution invariant (every future resolves — 100, always),
    # and the capacity ratio surviving a pooled-path outage
    "chaos_availability_pct": "higher",
    "chaos_resolution_pct": "eq",
    "chaos_degraded_vs_healthy_x": "higher",
    # replicated serving (PR 9): the failover availability floor, the
    # 100%-resolution and zero-lost-futures invariants of the replica
    # storm, the bitwise warm-restart admission, and the hedged-p99
    # tail-latency win against a straggling replica
    "replica_availability_pct": "higher",
    "replica_resolution_pct": "eq",
    "replica_lost_futures": "eq",
    "replica_warm_restart_bitwise": "eq",
    "replica_flap_resolution_pct": "eq",
    "replica_hedge_p99_gain_x": "higher",
    # device-mesh sharded serving (PR 10): the bitwise-equality audit —
    # sharded scores must equal single-device EXACTLY (the committed
    # baseline records 0.0, so ``eq`` pins fresh runs to 0.0 too, not
    # merely "no worse") — across the stitched, fused-top-K,
    # shared-stream-dedup, bf16 and chunked-cursor serving paths, plus
    # the throughput-parity ratio of the sharded dispatch
    "mesh_exact_volume_err": "eq",
    "mesh_exact_fused_err": "eq",
    "mesh_exact_dedup_err": "eq",
    "mesh_exact_bf16_err": "eq",
    "mesh_exact_chunked_err": "eq",
    "mesh_winps_parity_x": "higher",
}

# absolute slack added on top of the relative tolerance for "lower"
# metrics: error metrics sit near 0 (any float fuzz would be an infinite
# relative regression), and the chunking overhead is a small-ratio
# timing row whose CI-runner noise floor is additive, not proportional
ABS_SLACK = {
    "serving_chunked_overhead_x": 0.35,
}

# absolute floors — acceptance invariants the committed artifact must
# carry regardless of what any baseline says: metric -> (floor, fresh
# slack).  The BASELINE value is held to the raw floor (the committed
# JSON records the claimed win); the FRESH value gets the additive
# slack, because timing ratios on a shared CI runner are noisy while
# the analytic memory ratio is not.
FLOORS = {
    # ISSUE acceptance: ≥4× lower peak output-side memory at the
    # long-stream serving row...
    "serving_fused_mem_x": (4.0, 0.0),
    # ...at ≥0.95× the stitched path's windows/s
    "serving_fused_winps_x": (0.95, 0.10),
    # ISSUE 9 acceptance: availability ≥ 95% across the replica-kill
    # storm — an invariant of the failover design, held outright (small
    # fresh slack: a shed request under CI-runner scheduling jitter)
    "replica_availability_pct": (95.0, 2.0),
    # hedging must actually cut the straggler tail: the benchmark
    # injects a 4×-hedge-delay straggler, so even a noisy CI runner
    # clears 1.1×; the committed baseline documents the full win
    "replica_hedge_p99_gain_x": (1.1, 0.0),
    # ISSUE 10 acceptance: the 8-device scaling row.  The per-device
    # work shrink is ANALYTIC (from the shard-tiled packing — no
    # timing noise, zero slack): each device must hold ≥4× less
    # arena×batch work than the single-device pool.  The parity ratio
    # is measured — the sharded dispatch on a 1-core CI host must keep
    # a usable fraction of single-device windows/s (real meshes, where
    # the 8 devices are 8 cores, turn the analytic row into speedup)
    "mesh_per_device_work_x": (4.0, 0.0),
    "mesh_winps_parity_x": (0.20, 0.10),
}

# gate-local metric specs (same format as plot_bench.TRACKED): metrics
# that only the gate reads
SPECS = {
    "serving_bf16_score_err": (
        "serving", "serving_bf16_storage", "max_rel_score_err",
    ),
    "serving_chunked_score_err": (
        "serving", "serving_chunked_longT", "max_rel_score_err",
    ),
    "serving_fused_exact_err": (
        "serving", "serving_fused_readout_longT", "exact_score_err",
    ),
    "serving_fused_frame_mismatches": (
        "serving", "serving_fused_readout_longT", "frame_mismatches",
    ),
    "chaos_availability_pct": (
        "chaos", "chaos_storm", "availability_pct",
    ),
    "chaos_resolution_pct": (
        "chaos", "chaos_storm", "resolution_pct",
    ),
    "chaos_degraded_vs_healthy_x": (
        "chaos", "chaos_degraded", "degraded_vs_healthy",
    ),
    "replica_availability_pct": (
        "chaos", "replica_storm", "availability_pct",
    ),
    "replica_resolution_pct": (
        "chaos", "replica_storm", "resolution_pct",
    ),
    "replica_lost_futures": (
        "chaos", "replica_storm", "lost_futures",
    ),
    "replica_warm_restart_bitwise": (
        "chaos", "replica_storm", "warm_restart_bitwise",
    ),
    "replica_flap_resolution_pct": (
        "chaos", "replica_flap", "resolution_pct",
    ),
    "replica_hedge_p99_gain_x": (
        "chaos", "replica_hedge", "hedge_p99_gain",
    ),
    "mesh_exact_volume_err": ("mesh", "mesh_exact_volume", "max_abs_err"),
    "mesh_exact_fused_err": ("mesh", "mesh_exact_fused_topk", "max_abs_err"),
    "mesh_exact_dedup_err": ("mesh", "mesh_exact_dedup", "max_abs_err"),
    "mesh_exact_bf16_err": ("mesh", "mesh_exact_bf16", "max_abs_err"),
    "mesh_exact_chunked_err": ("mesh", "mesh_exact_chunked", "max_abs_err"),
    "mesh_per_device_work_x": (
        "mesh", "mesh_scaling_d8", "per_device_work_x",
    ),
    "mesh_winps_parity_x": ("mesh", "mesh_scaling_d8", "winps_parity_x"),
}


def _value(run: dict, metric: str) -> float | None:
    if metric == "serving_pooled_over_seq_winps":
        a = plot_bench._value(run, "serving_pooled_winps")
        b = plot_bench._value(run, "serving_seq_winps")
        return a / b if a is not None and b not in (None, 0) else None
    if metric in SPECS:
        saved = plot_bench.TRACKED.get(metric)
        plot_bench.TRACKED[metric] = SPECS[metric]
        try:
            return plot_bench._value(run, metric)
        finally:
            if saved is None:
                del plot_bench.TRACKED[metric]
            else:
                plot_bench.TRACKED[metric] = saved
    return plot_bench._value(run, metric)


def _load_run(path: str) -> dict:
    """{suite: {row_name: record}} for every BENCH_*.json under path."""
    runs = plot_bench.collect([path])
    merged: dict = {}
    for _, run in runs:
        merged.update(run)
    return merged


def gate(
    fresh_dir: str,
    baseline_dir: str,
    tol: float,
    log=print,
    strict: bool = False,
) -> list[str]:
    """Returns the list of failure messages (empty = gate passes).

    ``strict`` turns rows missing from the fresh run into failures;
    by default they are loud warnings (CI runs the suites in separate
    jobs, so each job's fresh dir only carries its own suite's rows).
    Warnings are summarized so a silently skipped gate stays visible.
    """
    fresh = _load_run(fresh_dir)
    base = _load_run(baseline_dir)
    failures: list[str] = []
    missing_fresh: list[str] = []
    width = max(len(m) for m in GATED) + 2
    log(
        f"{'metric'.ljust(width)}{'baseline':>12}{'fresh':>12}"
        f"{'ratio':>8}  verdict"
    )
    for metric, direction in GATED.items():
        b = _value(base, metric)
        f = _value(fresh, metric)
        if f is None:
            if strict:
                # --strict: the fresh run MUST produce every gated row —
                # a missing row is a broken benchmark, not a pass
                failures.append(f"{metric}: missing from the fresh run")
                verdict = "MISSING (fresh, strict)"
            else:
                missing_fresh.append(metric)
                verdict = "missing (fresh) — WARNING"
            log(
                f"{metric.ljust(width)}{'—':>12}{'—':>12}{'—':>8}  {verdict}"
            )
            continue
        if b is None:
            # new metric without a committed baseline yet: report, skip
            log(
                f"{metric.ljust(width)}{'—':>12}{f:>12.3f}{'—':>8}  "
                "no baseline (skipped)"
            )
            continue
        ratio = f / b if b else float("inf")
        if direction == "higher":
            ok = f >= b * (1.0 - tol)
        elif direction == "lower":
            # per-metric absolute slack: a 0.0 error baseline would
            # otherwise make any nonzero fresh value an infinite
            # relative regression, and timing-ratio noise is additive
            ok = f <= max(
                b * (1.0 + tol), b + ABS_SLACK.get(metric, 1e-6)
            )
        else:  # eq
            ok = abs(f - b) <= 1e-6 * max(abs(b), 1.0)
        verdict = "ok" if ok else f"REGRESSION (>{tol:.0%} {direction})"
        log(
            f"{metric.ljust(width)}{b:>12.3f}{f:>12.3f}{ratio:>8.2f}  "
            f"{verdict}"
        )
        if not ok:
            failures.append(
                f"{metric}: fresh {f:.4g} vs baseline {b:.4g} "
                f"(direction={direction}, tol={tol:.0%})"
            )
    # absolute floors: acceptance invariants, not baseline-relative —
    # the committed baseline must carry the claimed win at the raw
    # floor, the fresh run at floor − slack (CI-runner timing noise)
    for metric, (floor, slack) in FLOORS.items():
        for tag, run, s in (("baseline", base, 0.0), ("fresh", fresh, slack)):
            v = _value(run, metric)
            if v is None:
                if tag == "fresh" and strict:
                    failures.append(
                        f"{metric} [{tag} floor]: missing from the fresh run"
                    )
                log(
                    f"{metric.ljust(width)}{'—':>12}{'—':>12}{'—':>8}  "
                    f"floor >= {floor - s:.2f} ({tag}): missing"
                    f"{' — FAILED (strict)' if tag == 'fresh' and strict else ' (skipped)'}"
                )
                continue
            ok = v >= floor - s
            log(
                f"{metric.ljust(width)}{floor - s:>12.3f}{v:>12.3f}"
                f"{'—':>8}  floor ({tag}): {'ok' if ok else 'FAILED'}"
            )
            if not ok:
                failures.append(
                    f"{metric} [{tag} floor]: {v:.4g} below the absolute "
                    f"floor {floor - s:.4g}"
                )
    if missing_fresh:
        log(
            f"WARNING: {len(missing_fresh)} gated metric(s) absent from "
            f"the fresh run and NOT checked: {', '.join(missing_fresh)} "
            "(pass --strict to fail on these)"
        )
    return failures


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__.split("\n")[0])
    ap.add_argument(
        "--fresh-dir",
        required=True,
        help="directory holding the fresh smoke BENCH_*.json artifacts",
    )
    ap.add_argument(
        "--baseline-dir",
        default=".",
        help="directory holding the committed baseline BENCH_*.json "
        "(default: the repo root)",
    )
    ap.add_argument(
        "--tolerance",
        type=float,
        default=0.25,
        help="allowed relative regression before the gate fails "
        "(default 0.25 = 25%%)",
    )
    ap.add_argument(
        "--strict",
        action="store_true",
        help="fail when a gated metric is missing from the fresh run "
        "(default: warn and skip — suites run in separate CI jobs)",
    )
    args = ap.parse_args()
    failures = gate(
        args.fresh_dir, args.baseline_dir, args.tolerance,
        strict=args.strict,
    )
    if failures:
        print("\nperf-regression gate FAILED:")
        for f in failures:
            print(f"  - {f}")
        sys.exit(1)
    print("\nperf-regression gate passed")


if __name__ == "__main__":
    main()
