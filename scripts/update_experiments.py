"""Inject the generated dry-run/roofline/variant tables into
EXPERIMENTS.md at the <!-- TABLE:* --> markers."""

import re
import sys

sys.path.insert(0, "scripts")
from make_experiments_tables import (  # noqa: E402
    dryrun_table,
    load,
    roofline_table,
    variants_table,
)


def main() -> None:
    recs = load("experiments/dryrun")
    doc = open("EXPERIMENTS.md").read()
    tables = {
        "DRYRUN": dryrun_table(recs),
        "ROOFLINE": roofline_table(recs),
        "VAR_LLAMA": variants_table(recs, "llama3-405b", "train_4k"),
        "VAR_ARCTIC": variants_table(recs, "arctic-480b", "train_4k"),
        "VAR_QWEN": variants_table(recs, "qwen2-1.5b", "train_4k"),
    }
    for key, table in tables.items():
        marker = f"<!-- TABLE:{key} -->"
        block = f"{marker}\n{table}\n<!-- /TABLE:{key} -->"
        if f"<!-- /TABLE:{key} -->" in doc:
            doc = re.sub(
                rf"<!-- TABLE:{key} -->.*?<!-- /TABLE:{key} -->",
                block,
                doc,
                flags=re.S,
            )
        else:
            doc = doc.replace(marker, block)
    open("EXPERIMENTS.md", "w").write(doc)
    print("EXPERIMENTS.md tables updated")


if __name__ == "__main__":
    main()
