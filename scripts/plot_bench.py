"""Plot the perf trajectory recorded in ``BENCH_*.json`` artifacts.

``benchmarks/run.py --json`` (and CI's bench-smoke job) writes one
``BENCH_<suite>.json`` per suite per run.  Point this script at any
number of those files — or at directories holding them, e.g. one
downloaded CI artifact dir per PR — and it renders the headline
trajectories the ROADMAP tracks:

  * fused vs unfused physical query latency (``BENCH_speed.json``)
  * stmul kernel v1 vs v2 latency (``BENCH_kernels.json``)
  * pooled vs per-tenant-sequential serving at the 8-request
    mixed-tenant batch — windows/s, batch p50/p99 and the pooled
    speedup — plus the bf16 grating-storage capacity factor, the
    shared-stream clip-dedup speedup (8 tenants fanning out over one
    clip vs the undeduped pooled baseline), the bounded-memory
    chunking row (constant peak buffer frames, overhead vs unbounded)
    and the fused detection-readout row (peak output-side memory vs
    the stitched volume, throughput ratio, exactness)
    (``BENCH_serving.json``)
  * availability under the injected fault storm — healthy-request
    fraction, future-resolution invariant, storm p99 and the
    degraded-rung capacity ratio (``BENCH_chaos.json``)

plus the derived speedup rows and, when present, the ablation
decomposition (``BENCH_ablation.json``).

A text table is always printed; if matplotlib is importable a PNG is
written too (``--out``, default ``bench_trajectory.png``).  With a
single snapshot the "trajectory" is one point per metric — still useful
as the at-a-glance table; with several labeled runs the PNG shows the
per-PR evolution.

Run:  PYTHONPATH=src python scripts/plot_bench.py [paths...] [--out f.png]
"""

from __future__ import annotations

import argparse
import glob
import json
import os

# metric -> (suite, row name[, key-in-derived]); the headline
# trajectories.  A 3-tuple reads a ``key=value`` pair out of the row's
# derived column (the serving suite reports several per row); a
# ``*_ms``-keyed value feeding a ``*_us`` metric is scaled to µs.
TRACKED = {
    "fused_query_us": ("speed", "sthc_query_fused_physical"),
    "unfused_query_us": ("speed", "sthc_query_unfused_physical"),
    "fused_vs_unfused_x": ("speed", "sthc_fused_vs_unfused_speedup"),
    "stream_query_us": ("speed", "sthc_stream_physical"),
    "stmul_v1_us": ("kernels", "stmul_pallas_v1"),
    "stmul_v2_us": ("kernels", "stmul_pallas_v2"),
    "stmul_v1_vs_v2_x": ("kernels", "stmul_v1_vs_v2_speedup"),
    "serving_pooled_p50_us": ("serving", "serving_pooled_t8", "p50_ms"),
    "serving_seq_p50_us": ("serving", "serving_sequential_t8", "p50_ms"),
    "serving_pooled_p99_us": ("serving", "serving_pooled_t8", "p99_ms"),
    "serving_pooled_winps": (
        "serving", "serving_pooled_t8", "windows_per_s",
    ),
    "serving_seq_winps": (
        "serving", "serving_sequential_t8", "windows_per_s",
    ),
    "serving_pooled_vs_seq_x": (
        "serving", "serving_pooled_vs_sequential_x",
    ),
    "serving_bf16_capacity_x": (
        "serving", "serving_bf16_storage", "capacity_x",
    ),
    # shared-stream fan-out: 8 tenants searching ONE clip, clip-dedup
    # (one forward FFT for the whole fan-out) vs the undeduped pooled
    # baseline
    "serving_shared_dedup_p50_us": (
        "serving", "serving_shared_dedup_t8", "p50_ms",
    ),
    "serving_shared_nodedup_p50_us": (
        "serving", "serving_shared_nodedup_t8", "p50_ms",
    ),
    "serving_shared_dedup_winps": (
        "serving", "serving_shared_dedup_t8", "windows_per_s",
    ),
    "serving_shared_dedup_x": (
        "serving", "serving_shared_dedup_vs_pooled_x",
    ),
    # bounded-memory stream chunking: constant peak buffer (frames) and
    # the chunking overhead factor vs the unbounded one-shot pass
    "serving_chunked_peak_frames": (
        "serving", "serving_chunked_longT", "peak_buffer_frames",
    ),
    "serving_chunked_overhead_x": (
        "serving", "serving_chunked_longT", "overhead_x",
    ),
    # fused in-kernel detection readout over the long stream: peak
    # output-side memory shrink vs the stitched-volume path, the
    # throughput ratio (≈1 expected — the win is memory, not speed) and
    # the two absolute memory footprints
    "serving_fused_mem_x": (
        "serving", "serving_fused_readout_longT", "mem_x",
    ),
    "serving_fused_winps_x": (
        "serving", "serving_fused_readout_longT", "winps_x",
    ),
    "serving_fused_winps": (
        "serving", "serving_fused_readout_longT", "fused_winps",
    ),
    "serving_stitched_winps": (
        "serving", "serving_fused_readout_longT", "stitched_winps",
    ),
    "serving_fused_out_mb": (
        "serving", "serving_fused_readout_longT", "fused_out_mb",
    ),
    "serving_stitched_out_mb": (
        "serving", "serving_fused_readout_longT", "stitched_out_mb",
    ),
    # chaos suite: availability under the injected fault storm, the
    # resolution invariant (every submitted future resolves), storm p99
    # and how much capacity the sequential rung keeps when the pooled
    # path is forced open
    "chaos_availability_pct": (
        "chaos", "chaos_storm", "availability_pct",
    ),
    "chaos_resolution_pct": (
        "chaos", "chaos_storm", "resolution_pct",
    ),
    "chaos_storm_p99_us": ("chaos", "chaos_storm", "p99_ms"),
    "chaos_degraded_vs_healthy_x": (
        "chaos", "chaos_degraded", "degraded_vs_healthy",
    ),
    # device-mesh sharded serving: windows/s of the sharded vs single-
    # device pooled dispatch, the throughput-parity ratio, and the
    # analytic per-device work shrink from the shard-tiled arena
    "mesh_stream_winps": ("mesh", "mesh_stream_d8", "windows_per_s"),
    "mesh_single_winps": ("mesh", "mesh_single", "windows_per_s"),
    "mesh_winps_parity_x": ("mesh", "mesh_scaling_d8", "winps_parity_x"),
    "mesh_per_device_work_x": (
        "mesh", "mesh_scaling_d8", "per_device_work_x",
    ),
}

# latency pairs plotted together (left panel) and speedups (right panel)
LATENCY_PAIRS = [
    ("fused_query_us", "unfused_query_us"),
    ("stmul_v2_us", "stmul_v1_us"),
    ("serving_pooled_p50_us", "serving_seq_p50_us"),
    ("serving_shared_dedup_p50_us", "serving_shared_nodedup_p50_us"),
]
SPEEDUPS = [
    "fused_vs_unfused_x",
    "stmul_v1_vs_v2_x",
    "serving_pooled_vs_seq_x",
    "serving_bf16_capacity_x",
    "serving_shared_dedup_x",
    "serving_fused_mem_x",
    "serving_fused_winps_x",
    "chaos_degraded_vs_healthy_x",
    "mesh_per_device_work_x",
    "mesh_winps_parity_x",
]


def collect(paths: list[str]) -> list[tuple[str, dict]]:
    """(label, {suite: {row_name: record}}) per run.

    A path that is a directory contributes one labeled run holding all
    its BENCH_*.json; a bare file joins the run labeled by its parent
    directory.
    """
    runs: dict[str, dict] = {}
    files: list[tuple[str, str]] = []
    for p in paths:
        if os.path.isdir(p):
            for f in sorted(glob.glob(os.path.join(p, "BENCH_*.json"))):
                files.append((p, f))
        elif os.path.isfile(p):
            files.append((os.path.dirname(p) or ".", p))
        else:
            raise FileNotFoundError(f"no such file or directory: {p}")
    if not files:
        raise FileNotFoundError(
            f"no BENCH_*.json found under {paths} — run "
            "`benchmarks/run.py --json` first"
        )
    for label, f in files:
        with open(f) as fh:
            data = json.load(fh)
        suite = data.get("suite", os.path.basename(f))
        rows = {r["name"]: r for r in data.get("rows", [])}
        runs.setdefault(label, {})[suite] = rows
    return sorted(runs.items())


def _value(run: dict, metric: str) -> float | None:
    spec = TRACKED[metric]
    suite, row_name = spec[0], spec[1]
    row = run.get(suite, {}).get(row_name)
    if row is None:
        return None
    if len(spec) == 3:  # key=value pair inside the derived column
        key = spec[2]
        for part in str(row["derived"]).split(";"):
            if part.startswith(key + "="):
                try:
                    v = float(part.split("=", 1)[1])
                except ValueError:
                    return None
                if metric.endswith("_us") and key.endswith("_ms"):
                    v *= 1e3
                return v
        return None
    if metric.endswith("_us"):
        v = row["us_per_call"]
        return float(v) if not isinstance(v, str) else None
    # speedup rows carry the value in the derived column
    try:
        return float(str(row["derived"]).rstrip("x"))
    except ValueError:
        return None


def text_table(runs: list[tuple[str, dict]]) -> None:
    metrics = list(TRACKED)
    width = max(len(m) for m in metrics) + 2
    header = "metric".ljust(width) + "".join(
        f"{label[-18:]:>20}" for label, _ in runs
    )
    print(header)
    print("-" * len(header))
    for m in metrics:
        cells = []
        for _, run in runs:
            v = _value(run, m)
            cells.append(f"{v:>20.2f}" if v is not None else f"{'—':>20}")
        print(m.ljust(width) + "".join(cells))
    # ablation decomposition, when an artifact carries it
    for label, run in runs:
        abl = run.get("ablation")
        if not abl:
            continue
        print(f"\nablation decomposition [{label}]:")
        for name, row in abl.items():
            if name.startswith("ablation_") and "acc=" in str(row["derived"]):
                print(f"  {name[len('ablation_'):]:24s} {row['derived']}")


def plot(runs: list[tuple[str, dict]], out: str) -> bool:
    try:
        import matplotlib

        matplotlib.use("Agg")
        import matplotlib.pyplot as plt
    except Exception:  # matplotlib optional: the text table is the fallback
        return False
    labels = [label for label, _ in runs]
    x = range(len(runs))
    fig, (ax_lat, ax_spd) = plt.subplots(1, 2, figsize=(11, 4.2))
    for new, old in LATENCY_PAIRS:
        for metric, style in ((new, "-o"), (old, "--s")):
            ys = [_value(run, metric) for _, run in runs]
            if any(y is not None for y in ys):
                ax_lat.plot(x, ys, style, label=metric)
    ax_lat.set_title("query / kernel latency")
    ax_lat.set_ylabel("µs per call")
    ax_lat.set_yscale("log")
    for metric in SPEEDUPS:
        ys = [_value(run, metric) for _, run in runs]
        if any(y is not None for y in ys):
            ax_spd.plot(x, ys, "-o", label=metric)
    ax_spd.axhline(1.0, color="gray", lw=0.8, ls=":")
    ax_spd.set_title("speedups (×)")
    for ax in (ax_lat, ax_spd):
        ax.set_xticks(list(x))
        ax.set_xticklabels(labels, rotation=30, ha="right", fontsize=8)
        ax.legend(fontsize=8)
        ax.grid(alpha=0.3)
    fig.tight_layout()
    fig.savefig(out, dpi=120)
    return True


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__.split("\n")[0])
    ap.add_argument(
        "paths",
        nargs="*",
        default=None,
        help="BENCH_*.json files or directories of them (one run per "
        "directory); default: the current directory",
    )
    ap.add_argument("--out", default="bench_trajectory.png",
                    help="PNG path (written only when matplotlib exists)")
    args = ap.parse_args()
    runs = collect(args.paths or ["."])
    text_table(runs)
    if plot(runs, args.out):
        print(f"\nwrote {args.out}")
    else:
        print("\n(matplotlib unavailable — text table only)")


if __name__ == "__main__":
    main()
